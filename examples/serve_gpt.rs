//! End-to-end serving driver (the repository's E2E validation workload).
//!
//! Drives the continuous-batching serve engine on the native compiler
//! stack — no AOT artifacts needed: each (model, seq-bucket) pair is
//! chunk-searched once, cached, and shared across requests. The same
//! open-loop GPT trace is replayed under a sweep of activation-memory
//! budgets, comparing the legacy back-to-back path against continuous
//! batching with memory-quoted admission.
//!
//! Reported: completions/rejections/preemptions, throughput, latency and
//! queueing-wait percentiles, measured peak vs budget — the serving-side
//! counterpart of the paper's "breaking the memory wall" claim (§4.2).
//!
//! Run: `cargo run --release --example serve_gpt`
//! (The PJRT artifact tier lives in `autochunkd serve`; see DESIGN.md §6.)

use autochunk::coordinator::{open_loop_workload, EngineConfig, ServeEngine};
use autochunk::util::pool;

fn main() -> autochunk::util::error::Result<()> {
    let threads = pool::num_threads();
    let buckets = vec![32usize, 64, 128];
    let requests = open_loop_workload(24, 8, 120, 4242, 3);
    println!(
        "workload: {} prefill requests, len 8..120, buckets {:?}, pool width {threads}\n",
        requests.len(),
        buckets
    );

    // Budgets relative to one dense top-bucket request.
    let mut probe = ServeEngine::new(EngineConfig {
        model: "gpt".into(),
        budget_bytes: usize::MAX,
        buckets: buckets.clone(),
        ..EngineConfig::default()
    });
    let (_, top) = probe.quote(*buckets.last().unwrap(), 0)?.expect("top bucket");

    for (label, mult_num, mult_den) in [("0.6x", 3usize, 5usize), ("1.5x", 3, 2), ("3x", 3, 1)] {
        let budget = top.peak_bytes * mult_num / mult_den;
        println!(
            "---- budget {label} of one dense s{} request ({:.1} MiB) ----",
            buckets.last().unwrap(),
            budget as f64 / (1 << 20) as f64
        );
        for mode in ["serial    ", "continuous"] {
            let mut engine = ServeEngine::new(EngineConfig {
                model: "gpt".into(),
                budget_bytes: budget,
                max_batch: 8,
                buckets: buckets.clone(),
                ..EngineConfig::default()
            });
            let (responses, report) = if mode.trim() == "serial" {
                engine.serve_serial(&requests)?
            } else {
                engine.serve(&requests)?
            };
            debug_assert_eq!(responses.len(), requests.len());
            println!(
                "{mode} | served {:>2}/{} rejected {:>2} preempted {:>2} | {:>6.2} req/s | \
                 wait p50 {:>6.1} ms p99 {:>6.1} ms | peak {:>5.1}/{:.1} MiB | waves {}",
                report.completed,
                requests.len(),
                report.rejected,
                report.preempted,
                report.throughput_rps,
                report.wait_p50_us as f64 / 1e3,
                report.wait_p99_us as f64 / 1e3,
                report.measured_peak_bytes as f64 / (1 << 20) as f64,
                budget as f64 / (1 << 20) as f64,
                report.waves,
            );
        }
        println!();
    }
    println!(
        "(sub-request budgets force preemption to deeper-chunked plans — the memory wall \
         breaks instead of rejecting; generous budgets convert headroom into co-residency \
         and chunk concurrency)"
    );
    Ok(())
}
