//! End-to-end serving driver (the repository's E2E validation workload).
//!
//! Drives the continuous-batching serve engine on the native compiler
//! stack — no AOT artifacts needed — through the full generation path
//! (DESIGN.md §13): every request runs one chunk-planned causal prefill
//! that seeds a KV cache, then autoregressive decode steps scheduled in
//! memory-aware waves, with admission pricing `planned_peak +
//! resident_kv_bytes` as caches grow and evicting caches as requests
//! finish. The same open-loop trace is replayed under a sweep of
//! activation-memory budgets, comparing the legacy back-to-back path
//! against continuous batching.
//!
//! Reported: completions/rejections/preemptions, tokens generated,
//! prefill vs decode latency breakdown, resident-KV high water, measured
//! peak vs budget — the serving-side counterpart of the paper's
//! "breaking the memory wall" claim (§4.2).
//!
//! Run: `cargo run --release --example serve_gpt`
//! (The PJRT artifact tier lives in `autochunkd serve`; see DESIGN.md §6.)

use autochunk::coordinator::{generate_workload, EngineConfig, ServeEngine};
use autochunk::util::pool;

fn main() -> autochunk::util::error::Result<()> {
    let threads = pool::num_threads();
    let buckets = vec![32usize, 64, 128];
    // prompts of 8..100 tokens, each generating 2..8 new tokens
    let requests = generate_workload(16, 8, 100, 2, 8, 4242, 3);
    let total_new: usize = requests.iter().map(|r| r.max_new_tokens).sum();
    println!(
        "workload: {} generation requests (prompts 8..100, {} tokens to generate), \
         buckets {:?}, pool width {threads}\n",
        requests.len(),
        total_new,
        buckets
    );

    // Budgets relative to one dense top-bucket request plus its cache.
    let mut probe = ServeEngine::new(EngineConfig {
        model: "gpt".into(),
        budget_bytes: usize::MAX,
        buckets: buckets.clone(),
        ..EngineConfig::default()
    });
    let (_, top) = probe.quote(*buckets.last().unwrap(), 0)?.expect("top bucket");
    let unit = top.peak_bytes + probe.kv_bytes(*buckets.last().unwrap());

    for (label, mult_num, mult_den) in [("0.8x", 4usize, 5usize), ("1.5x", 3, 2), ("3x", 3, 1)] {
        let budget = unit * mult_num / mult_den;
        println!(
            "---- budget {label} of one dense s{} generation ({:.1} MiB) ----",
            buckets.last().unwrap(),
            budget as f64 / (1 << 20) as f64
        );
        for mode in ["serial    ", "continuous"] {
            let mut engine = ServeEngine::new(EngineConfig {
                model: "gpt".into(),
                budget_bytes: budget,
                max_batch: 8,
                buckets: buckets.clone(),
                ..EngineConfig::default()
            });
            let (responses, report) = if mode.trim() == "serial" {
                engine.serve_serial(&requests)?
            } else {
                engine.serve(&requests)?
            };
            debug_assert_eq!(responses.len(), requests.len());
            println!(
                "{mode} | served {:>2}/{} rejected {:>2} preempted {:>2} | {:>6.2} req/s | \
                 {:>4} tok generated | decode p50 {:>6.2} ms p99 {:>6.2} ms | \
                 kv high-water {:>5.1} MiB | peak {:>5.1}/{:.1} MiB | waves {}",
                report.completed,
                requests.len(),
                report.rejected,
                report.preempted,
                report.throughput_rps,
                report.generated_tokens,
                report.decode_p50_us as f64 / 1e3,
                report.decode_p99_us as f64 / 1e3,
                report.resident_kv_high_water_bytes as f64 / (1 << 20) as f64,
                report.measured_peak_bytes as f64 / (1 << 20) as f64,
                budget as f64 / (1 << 20) as f64,
                report.waves,
            );
        }
        println!();
    }
    println!(
        "(per-step decode peak is O(s) where prefill is O(s²), so generous budgets pack \
         many decoding requests per wave; resident caches are priced into admission and \
         evicted the moment a request finishes)"
    );
    Ok(())
}
