//! End-to-end serving driver (the repository's E2E validation workload).
//!
//! Loads the AOT-compiled GPT artifacts (JAX -> HLO text -> PJRT; run
//! `make artifacts` first), then serves the same synthetic batched
//! workload under a sweep of activation-memory budgets, comparing the
//! dense-only baseline against the full AutoChunk variant set
//! (dense / chunked / Pallas-fused attention).
//!
//! Reported: completion + rejection counts, latency percentiles, and
//! throughput -- the serving-side counterpart of the paper's "breaking
//! the memory wall" claim (section 4.2). Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example serve_gpt`

use autochunk::coordinator::{synthetic_workload, Coordinator, RequestOutcome, ServeConfig};

fn main() -> autochunk::util::error::Result<()> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let requests = synthetic_workload(48, 32, 256, 4242);
    println!(
        "workload: {} prefill requests, len 32..256, buckets 64/128/256\n",
        requests.len()
    );

    for budget_mb in [1usize, 2, 4, 16] {
        for (label, modes) in [
            ("dense-only", vec!["dense".to_string()]),
            ("autochunk ", Vec::new()),
        ] {
            let mut coord = Coordinator::new(ServeConfig {
                artifacts_dir: dir.clone(),
                budget_bytes: budget_mb << 20,
                max_batch: 8,
                model: "gpt".into(),
                allowed_modes: modes,
                ..ServeConfig::default()
            })?;
            let (responses, report) = coord.serve(&requests)?;
            let rejected = responses
                .iter()
                .filter(|r| r.outcome == RequestOutcome::Rejected)
                .count();
            println!(
                "budget {budget_mb:>2} MiB | {label} | served {:>2}/{} rejected {:>2} | {:>6.2} req/s | p50 {:>7.2} ms p95 {:>7.2} ms",
                report.completed,
                requests.len(),
                rejected,
                report.throughput_rps,
                report.p50_us as f64 / 1e3,
                report.p95_us as f64 / 1e3,
            );
        }
    }
    println!("\n(autochunk's chunked/fused variants keep serving under budgets where dense-only rejects)");
    Ok(())
}
