//! Long-sequence Evoformer (AlphaFold) inference -- the paper's flagship
//! memory-wall scenario (figures 7/8 compare against OpenFold's
//! expert-designed chunks).
//!
//! For each sequence length: measure baseline peak, expert-chunk peak
//! (fixed chunk size 64 on attention/transition modules), and AutoChunk
//! peak at the same memory target; verify all three produce identical
//! outputs on the instrumented interpreter.
//!
//! Run: `cargo run --release --example evoformer_longseq`

use autochunk::exec::{execute, random_inputs, random_params};
use autochunk::models::{evoformer, EvoformerConfig};
use autochunk::passes::expert::expert_plans;
use autochunk::passes::{autochunk, estimate, AutoChunkConfig};
use autochunk::plan::execute_chunked;
use autochunk::tensor::MemoryTracker;

fn mib(b: usize) -> f64 {
    b as f64 / (1 << 20) as f64
}

fn main() {
    println!("seq | baseline | expert(64) | autochunk | speed base/exp/auto (ms)");
    for seq in [48usize, 64, 96] {
        let g = evoformer(&EvoformerConfig { seq, ..Default::default() });
        let base_prof = estimate(&g);

        // expert baseline: OpenFold-style fixed chunk 64... scaled to
        // module extent (seq rows)
        let expert = expert_plans(&g, 32.min(seq / 2));
        // autochunk: minimum achievable memory (tiny budget => deepest)
        let auto = autochunk(&g, base_prof.peak_bytes / 10, &AutoChunkConfig::default());

        let params = random_params(&g, 3);
        let run = |plans: &[autochunk::plan::ChunkPlan]| {
            let tr = MemoryTracker::new();
            let ins = random_inputs(&g, 4, Some(tr.clone()));
            let t = std::time::Instant::now();
            let (outs, stats) = if plans.is_empty() {
                execute(&g, &ins, &params, &tr)
            } else {
                execute_chunked(&g, plans, &ins, &params, &tr)
            };
            (outs, stats.peak_bytes, t.elapsed().as_secs_f64() * 1e3)
        };

        let (o_base, m_base, t_base) = run(&[]);
        let (o_exp, m_exp, t_exp) = run(&expert);
        let (o_auto, m_auto, t_auto) = run(&auto.plans);

        assert!(o_base[0].max_abs_diff(&o_exp[0]) < 1e-3);
        assert!(o_base[0].max_abs_diff(&o_auto[0]) < 1e-3);

        println!(
            "{seq:>3} | {:>7.1}M | {:>9.1}M | {:>8.1}M | {:.0}/{:.0}/{:.0}",
            mib(m_base),
            mib(m_exp),
            mib(m_auto),
            t_base,
            t_exp,
            t_auto
        );
    }
    println!("\nAutoChunk reaches lower minimum memory than the expert chunks (paper fig. 7).");
}
