//! Figure-1 view: activation memory vs sequence length, with and without
//! AutoChunk, for all four evaluation models -- plus the max-length
//! extension factor under a fixed memory cap (paper section 4.2: 11.7x
//! for 1D inputs, ~3.2x for 2D).
//!
//! Run: `cargo run --release --example memory_wall`

use autochunk::models::{evoformer, gpt, unet, vit, EvoformerConfig, GptConfig, UNetConfig, ViTConfig};
use autochunk::passes::{autochunk, estimate, AutoChunkConfig};

fn mib(b: usize) -> f64 {
    b as f64 / (1 << 20) as f64
}

fn main() {
    let cfg = AutoChunkConfig::default();
    println!("model      seq    baseline  autochunk  reduction");
    let mut rows: Vec<(&str, usize, usize, usize)> = Vec::new();
    for seq in [256usize, 512, 1024, 2048] {
        let g = gpt(&GptConfig { seq, ..Default::default() });
        let b = estimate(&g).peak_bytes;
        let a = autochunk(&g, b / 10, &cfg).chunked_peak;
        rows.push(("gpt", seq, b, a));
    }
    for seq in [256usize, 512, 1024] {
        let g = vit(&ViTConfig { patches: seq, ..Default::default() });
        let b = estimate(&g).peak_bytes;
        let a = autochunk(&g, b / 10, &cfg).chunked_peak;
        rows.push(("vit", seq, b, a));
    }
    for seq in [32usize, 48, 64] {
        let g = evoformer(&EvoformerConfig { seq, ..Default::default() });
        let b = estimate(&g).peak_bytes;
        let a = autochunk(&g, b / 10, &cfg).chunked_peak;
        rows.push(("evoformer", seq, b, a));
    }
    for seq in [32usize, 64] {
        let g = unet(&UNetConfig { image: seq, ..Default::default() });
        let b = estimate(&g).peak_bytes;
        let a = autochunk(&g, b / 10, &cfg).chunked_peak;
        rows.push(("unet", seq, b, a));
    }
    for (m, s, b, a) in &rows {
        println!(
            "{m:<10} {s:>4}  {:>8.1}M  {:>8.1}M  {:>6.1}%",
            mib(*b),
            mib(*a),
            100.0 * (1.0 - *a as f64 / *b as f64)
        );
    }

    // Max-length extension: the largest seq whose (chunked) peak fits the
    // cap that the *baseline* just saturates at its shortest seq.
    println!("\nmax-seq extension under a fixed activation cap:");
    let cap = estimate(&gpt(&GptConfig { seq: 1024, ..Default::default() })).peak_bytes;
    let max_seq = |chunked: bool| -> usize {
        let mut best = 0;
        for seq in [1024usize, 2048, 4096, 8192, 12288, 16384] {
            let g = gpt(&GptConfig { seq, ..Default::default() });
            let peak = if chunked {
                autochunk(&g, cap, &AutoChunkConfig::default()).chunked_peak
            } else {
                estimate(&g).peak_bytes
            };
            if peak <= cap {
                best = seq;
            }
        }
        best
    };
    let plain = max_seq(false);
    let chunked = max_seq(true);
    println!(
        "  gpt (1D): cap {:.0} MiB: {} -> {} tokens  ({:.1}x)",
        mib(cap),
        plain,
        chunked,
        chunked as f64 / plain as f64
    );
}
