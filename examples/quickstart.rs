//! Quickstart: `autochunk(model, memory_budget)` on a GPT prefill graph.
//!
//! Builds the model, runs the AutoChunk compiler for a 25% activation
//! budget, executes both the original and the chunked graph on the
//! instrumented interpreter, and verifies (a) identical outputs and
//! (b) the measured peak matches the compiler's promise.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Threading: the kernels, the chunk loops, and the compiler's search all
//! run on an internal scoped thread pool sized by the `AUTOCHUNK_THREADS`
//! environment variable (default: all cores; `1` = exact legacy serial
//! behaviour — results are bitwise identical at every width):
//!
//! ```text
//! AUTOCHUNK_THREADS=4 cargo run --release --example quickstart
//! ```
//!
//! When a budget is passed to the chunked executor
//! (`plan::ExecOptions { budget_bytes }`), leftover headroom additionally
//! buys concurrent chunk iterations — see DESIGN.md §4.

use autochunk::exec::{execute, random_inputs, random_params};
use autochunk::models::{gpt, GptConfig};
use autochunk::passes::{autochunk, estimate, AutoChunkConfig};
use autochunk::plan::execute_chunked;
use autochunk::tensor::MemoryTracker;

fn mib(b: usize) -> f64 {
    b as f64 / (1 << 20) as f64
}

fn main() {
    // 1. a model (GPT prefill, 1k tokens)
    let cfg = GptConfig { seq: 1024, layers: 4, ..Default::default() };
    let graph = gpt(&cfg);
    println!(
        "model: gpt seq={} layers={} -> {} IR nodes (pool width {}; set AUTOCHUNK_THREADS to change)",
        cfg.seq,
        cfg.layers,
        graph.len(),
        autochunk::util::pool::num_threads()
    );

    // 2. the one-line API: chunk plans for a 25% activation budget
    let baseline = estimate(&graph);
    let budget = baseline.peak_bytes / 4;
    println!(
        "baseline activation peak: {:.1} MiB; budget: {:.1} MiB",
        mib(baseline.peak_bytes),
        mib(budget)
    );
    let t0 = std::time::Instant::now();
    let result = autochunk(&graph, budget, &AutoChunkConfig::default());
    println!(
        "autochunk: {} plans in {:.0} ms; estimated chunked peak {:.1} MiB ({:.1}%)",
        result.plans.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        mib(result.chunked_peak),
        100.0 * result.chunked_peak as f64 / baseline.peak_bytes as f64
    );
    for (i, p) in result.plans.iter().enumerate() {
        println!(
            "  plan {i}: {} nodes, chunk dim {} x n={}",
            p.region.len(),
            p.outputs[0].1,
            p.n_chunks
        );
    }

    // 3. execute both ways and compare
    let params = random_params(&graph, 1);
    let t_base = MemoryTracker::new();
    let inputs = random_inputs(&graph, 2, Some(t_base.clone()));
    let w0 = std::time::Instant::now();
    let (out_base, stats_base) = execute(&graph, &inputs, &params, &t_base);
    let base_ms = w0.elapsed().as_secs_f64() * 1e3;

    let t_chunk = MemoryTracker::new();
    let inputs_c = random_inputs(&graph, 2, Some(t_chunk.clone()));
    let w1 = std::time::Instant::now();
    let (out_chunk, stats_chunk) = execute_chunked(&graph, &result.plans, &inputs_c, &params, &t_chunk);
    let chunk_ms = w1.elapsed().as_secs_f64() * 1e3;

    let diff = out_base[0].max_abs_diff(&out_chunk[0]);
    println!("\nmeasured on the instrumented interpreter:");
    println!(
        "  original: peak {:.1} MiB, {:.0} ms",
        mib(stats_base.peak_bytes),
        base_ms
    );
    println!(
        "  chunked : peak {:.1} MiB, {:.0} ms ({:+.1}% time)",
        mib(stats_chunk.peak_bytes),
        chunk_ms,
        100.0 * (chunk_ms - base_ms) / base_ms
    );
    println!("  max |delta output| = {diff:.2e}");
    assert!(diff < 1e-3, "outputs diverged");
    assert!(stats_chunk.peak_bytes < stats_base.peak_bytes / 2);
    println!("\nOK: same numerics, {:.1}x less activation memory",
        stats_base.peak_bytes as f64 / stats_chunk.peak_bytes as f64);
}
