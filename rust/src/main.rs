//! `autochunkd` — the AutoChunk leader binary.
//!
//! Subcommands:
//!
//! * `compile` — run the AutoChunk passes on a built-in model and print
//!   the chosen chunk plans and memory numbers;
//! * `profile` — print the per-operator activation-memory profile
//!   (Figure 4 view) of a model;
//! * `import`  — import an AOT HLO artifact into the IR and run the
//!   compiler over it;
//! * `serve`   — serve a synthetic workload from AOT artifacts through
//!   the PJRT runtime under a memory budget, reporting latency/throughput.
//!
//! Arguments are `--key value` pairs (hand-rolled parser; no clap in the
//! vendored dependency set).

use autochunk::coordinator::{synthetic_workload, Coordinator, ServeConfig};
use autochunk::models;
use autochunk::passes::{autochunk, estimate, AutoChunkConfig};
use autochunk::util::error::Result;
use autochunk::{anyhow, bail};
use std::collections::HashMap;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal `--key value` argument map.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = HashMap::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{k}'"))?
                .to_string();
            let val = it.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            kv.insert(key, val);
        }
        Ok(Args { cmd, kv })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.into())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.kv.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.kv.get(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }
}

fn build_model(name: &str, seq: usize) -> Result<autochunk::ir::Graph> {
    Ok(match name {
        "gpt" => models::gpt(&models::GptConfig { seq, ..Default::default() }),
        "gpt-fused" => models::gpt(&models::GptConfig {
            seq,
            fused_attention: true,
            ..Default::default()
        }),
        "vit" => models::vit(&models::ViTConfig { patches: seq, ..Default::default() }),
        "evoformer" => models::evoformer(&models::EvoformerConfig {
            seq,
            ..Default::default()
        }),
        "unet" => models::unet(&models::UNetConfig { image: seq, ..Default::default() }),
        other => bail!("unknown model '{other}' (gpt|gpt-fused|vit|evoformer|unet)"),
    })
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "compile" => cmd_compile(&args),
        "profile" => cmd_profile(&args),
        "import" => cmd_import(&args),
        "serve" => cmd_serve(&args),
        _ => {
            println!(
                "autochunkd — AutoChunk reproduction (see README.md)\n\n\
                 usage:\n\
                 \x20 autochunkd compile --model gpt --seq 1024 --budget-frac 0.2\n\
                 \x20 autochunkd profile --model evoformer --seq 64\n\
                 \x20 autochunkd import  --hlo artifacts/gpt_dense_s128.hlo.txt --budget-frac 0.5\n\
                 \x20 autochunkd serve   --artifacts artifacts --budget-mb 8 --requests 32"
            );
            Ok(())
        }
    }
}

fn cmd_compile(args: &Args) -> Result<()> {
    let model = args.get("model", "gpt");
    let seq = args.get_usize("seq", 1024)?;
    let frac = args.get_f64("budget-frac", 0.2)?;
    let graph = build_model(&model, seq)?;
    let profile = estimate(&graph);
    let budget = (profile.peak_bytes as f64 * frac) as usize;
    println!(
        "model={model} seq={seq} nodes={} baseline_peak={:.2} MiB budget={:.2} MiB",
        graph.len(),
        profile.peak_bytes as f64 / (1 << 20) as f64,
        budget as f64 / (1 << 20) as f64
    );
    let t0 = std::time::Instant::now();
    let result = autochunk(&graph, budget, &AutoChunkConfig::default());
    println!(
        "compile time: {:.1} ms; {} plan(s); chunked_peak={:.2} MiB ({:.1}% of baseline); cost={:.3}",
        t0.elapsed().as_secs_f64() * 1e3,
        result.plans.len(),
        result.chunked_peak as f64 / (1 << 20) as f64,
        100.0 * result.chunked_peak as f64 / result.baseline_peak as f64,
        result.total_cost,
    );
    for (i, p) in result.plans.iter().enumerate() {
        let (o, d) = p.outputs[0];
        println!(
            "  plan {i}: region [{}..{}] ({} nodes) chunk dim {d} of {:?} n={}",
            p.region.first().unwrap(),
            p.region.last().unwrap(),
            p.region.len(),
            graph.node(o).shape,
            p.n_chunks
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model = args.get("model", "gpt");
    let seq = args.get_usize("seq", 512)?;
    let graph = build_model(&model, seq)?;
    let profile = estimate(&graph);
    println!("# node  op  live_MiB   (peak at node {})", profile.peak_node);
    for (i, &bytes) in profile.per_node.iter().enumerate() {
        println!(
            "{i}\t{}\t{:.3}",
            graph.node(i).op.mnemonic(),
            bytes as f64 / (1 << 20) as f64
        );
    }
    println!(
        "# fraction of nodes below 30% of peak: {:.1}%",
        100.0 * profile.fraction_below(0.3)
    );
    Ok(())
}

fn cmd_import(args: &Args) -> Result<()> {
    let path = args
        .kv
        .get("hlo")
        .ok_or_else(|| anyhow!("--hlo <path> required"))?;
    let frac = args.get_f64("budget-frac", 0.5)?;
    let graph = autochunk::hlo::parse_hlo_file(path)?;
    let profile = estimate(&graph);
    println!(
        "imported {} nodes from {path}; baseline_peak={:.2} MiB",
        graph.len(),
        profile.peak_bytes as f64 / (1 << 20) as f64
    );
    let budget = (profile.peak_bytes as f64 * frac) as usize;
    let result = autochunk(&graph, budget, &AutoChunkConfig::default());
    println!(
        "{} plan(s); chunked_peak={:.2} MiB ({:.1}%)",
        result.plans.len(),
        result.chunked_peak as f64 / (1 << 20) as f64,
        100.0 * result.chunked_peak as f64 / result.baseline_peak as f64
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.get("artifacts", "artifacts");
    let budget_mb = args.get_usize("budget-mb", 8)?;
    let n = args.get_usize("requests", 32)?;
    let min_len = args.get_usize("min-len", 32)?;
    let max_len = args.get_usize("max-len", 256)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let modes: Vec<String> = args
        .kv
        .get("modes")
        .map(|m| m.split(',').map(|s| s.to_string()).collect())
        .unwrap_or_default();

    let mut coord = Coordinator::new(ServeConfig {
        artifacts_dir: dir,
        budget_bytes: budget_mb << 20,
        max_batch: args.get_usize("max-batch", 8)?,
        model: args.get("model", "gpt"),
        allowed_modes: modes,
        worker_threads: args.get_usize("threads", 0)?,
    })?;
    let requests = synthetic_workload(n, min_len, max_len, seed);
    println!(
        "serving {n} requests (len {min_len}..{max_len}) under {budget_mb} MiB activation budget"
    );
    let (_, report) = coord.serve(&requests)?;
    println!("{}", report.render());
    Ok(())
}
