//! The node-at-a-time interpreter.

use crate::ir::{Graph, Node, NodeId, Op};
use crate::tensor::conv::{avgpool2x_nchw, conv2d};
use crate::tensor::layout::{concat, gather_rows, upsample2x_nchw};
use crate::tensor::ops::{binary, to_f32, unary};
use crate::tensor::reduce::{reduce, softmax};
use crate::tensor::matmul::matmul;
use crate::tensor::{MemoryTracker, Tensor};

/// Execution statistics for one run.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Peak tracked bytes during the run.
    pub peak_bytes: usize,
    /// Number of nodes executed (chunked bodies count once per chunk).
    pub nodes_executed: usize,
    /// Pool width the run was entered with ([`crate::util::pool::num_threads`]).
    pub threads: usize,
    /// Largest in-flight chunk-iteration count the governor granted
    /// (0 for unchunked runs, 1 when chunk loops ran serially).
    pub max_chunk_degree: usize,
    /// Main-arena high-water mark in planned bytes (arena runs only;
    /// equals the planner's `planned_peak_bytes` exactly).
    pub arena_peak_bytes: usize,
    /// Fresh slot-storage allocations this run (cold-cache misses).
    pub arena_fresh_allocs: usize,
    /// Slot acquires served from recycled storage this run.
    pub arena_reuses: usize,
    /// Largest per-lane sub-arena high-water mark across chunk regions
    /// (equals the planner's `lane_bytes` for the executed regions).
    pub lane_peak_bytes: usize,
    /// Bytes copied out to the slow spill tier this run (offload
    /// decisions; 0 unless the plan carries spill decisions).
    pub spill_out_bytes: usize,
    /// Bytes copied back from the slow tier at restore points.
    pub spill_in_bytes: usize,
    /// Spill-script events executed (offload spills + all restores).
    pub spill_events: usize,
    /// Restores served by re-executing the producing node instead of a
    /// slow-tier copy.
    pub spill_recomputes: usize,
}

/// Execute `graph` with positional `inputs`/`params`; intermediates land on
/// `tracker`. Returns output tensors (in `graph.outputs` order) and stats.
pub fn execute(
    graph: &Graph,
    inputs: &[Tensor],
    params: &[Tensor],
    tracker: &MemoryTracker,
) -> (Vec<Tensor>, ExecStats) {
    execute_traced(graph, inputs, params, tracker, None)
}

/// [`execute`] with an optional trace scope: each executed node records
/// a span named by its op mnemonic (DESIGN.md §19). `None` is the plain
/// interpreter — the trace branch costs one `Option` test per node.
pub fn execute_traced(
    graph: &Graph,
    inputs: &[Tensor],
    params: &[Tensor],
    tracker: &MemoryTracker,
    trace: Option<&crate::util::trace::TraceScope>,
) -> (Vec<Tensor>, ExecStats) {
    assert_eq!(inputs.len(), graph.inputs.len(), "input arity");
    assert_eq!(params.len(), graph.params.len(), "param arity");

    // Liveness: refcount = #users + 1 if graph output.
    let users = graph.users();
    let mut refcount: Vec<usize> = users.iter().map(|u| u.len()).collect();
    for &o in &graph.outputs {
        refcount[o] += 1;
    }

    let mut values: Vec<Option<Tensor>> = vec![None; graph.len()];
    for (pos, &id) in graph.inputs.iter().enumerate() {
        assert_eq!(
            inputs[pos].shape(),
            graph.node(id).shape.as_slice(),
            "input {pos} shape mismatch"
        );
        values[id] = Some(inputs[pos].clone());
    }
    for (pos, &id) in graph.params.iter().enumerate() {
        assert_eq!(
            params[pos].shape(),
            graph.node(id).shape.as_slice(),
            "param {pos} shape mismatch"
        );
        values[id] = Some(params[pos].clone());
    }

    let mut stats = ExecStats {
        threads: crate::util::pool::num_threads(),
        ..ExecStats::default()
    };
    for node in &graph.nodes {
        if values[node.id].is_some() {
            // leaf already bound
            continue;
        }
        let out = match trace {
            Some(ts) => {
                let sp = ts.begin();
                let out = execute_node(node, &values, tracker);
                ts.end(
                    sp,
                    &node.op.mnemonic(),
                    vec![("node", crate::util::trace::ArgV::U(node.id as u64))],
                );
                out
            }
            None => execute_node(node, &values, tracker),
        };
        stats.nodes_executed += 1;
        values[node.id] = Some(out);
        // Release inputs whose last consumer this was.
        for &i in &node.inputs {
            refcount[i] -= 1;
            if refcount[i] == 0 {
                values[i] = None;
            }
        }
    }

    let outputs: Vec<Tensor> = graph
        .outputs
        .iter()
        .map(|&o| values[o].clone().expect("output not computed"))
        .collect();
    stats.peak_bytes = tracker.peak();
    (outputs, stats)
}

/// Execute a single node against already-computed `values`.
pub fn execute_node(node: &Node, values: &[Option<Tensor>], tracker: &MemoryTracker) -> Tensor {
    let tr = Some(tracker.clone());
    let arg = |i: usize| -> &Tensor {
        values[node.inputs[i]]
            .as_ref()
            .unwrap_or_else(|| panic!("value {} not live for node {}", node.inputs[i], node.id))
    };
    match &node.op {
        Op::Input | Op::Param => unreachable!("leaves are pre-bound"),
        Op::Const(v) => Tensor::from_f32(vec![*v], &[], tr).reshape(&node.shape, None),
        Op::Iota { axis } => Tensor::iota(&node.shape, *axis, tr),
        Op::Binary(op) => binary(*op, arg(0), arg(1), tr),
        Op::Unary(op) => unary(*op, arg(0), tr),
        Op::MatMul => matmul(arg(0), arg(1), tr),
        Op::DotGeneral {
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
        } => dot_general(
            arg(0),
            arg(1),
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
            tracker,
        ),
        Op::Transpose { perm } => arg(0).permute(perm),
        Op::Reshape => arg(0).reshape(&node.shape, tr),
        Op::Broadcast { dims } => {
            // Map input dims onto the output shape (XLA broadcast_in_dim).
            let a = arg(0);
            let mut reshaped = vec![1usize; node.shape.len()];
            for (i, &d) in dims.iter().enumerate() {
                reshaped[d] = a.shape()[i];
            }
            a.reshape(&reshaped, tr).broadcast_to(&node.shape)
        }
        Op::Reduce { op, axis, keepdims } => reduce(*op, arg(0), *axis, *keepdims, tr),
        Op::Softmax { axis } => softmax(arg(0), *axis, tr),
        Op::Concat { axis } => {
            let parts: Vec<Tensor> =
                node.inputs.iter().map(|&i| values[i].clone().unwrap()).collect();
            concat(&parts, *axis, tr)
        }
        Op::Slice { axis, start, len } => arg(0).slice_axis(*axis, *start, *len),
        Op::Gather => gather_rows(arg(0), arg(1), tr),
        Op::Conv2d { stride, pad } => conv2d(arg(0), arg(1), *stride, *pad, tr),
        Op::AvgPool2x => avgpool2x_nchw(arg(0), tr),
        Op::Upsample2x => upsample2x_nchw(arg(0), tr),
        Op::Convert => to_f32(arg(0), tr),
        Op::FusedAttention { scale } => {
            if node.inputs.len() > 3 {
                crate::tensor::attention::fused_attention_pos(
                    arg(0),
                    arg(1),
                    arg(2),
                    arg(3),
                    *scale,
                    tr,
                )
            } else {
                crate::tensor::attention::fused_attention(arg(0), arg(1), arg(2), *scale, tr)
            }
        }
        Op::Opaque { kind } => panic!("opaque op '{kind}' is analysis-only (execute via PJRT)"),
    }
}

/// General dot via canonicalization to batched matmul:
/// permute to [batch..., free..., contract...] on both sides, reshape to
/// 3-D, matmul, reshape back.
fn dot_general(
    a: &Tensor,
    b: &Tensor,
    lhs_batch: &[usize],
    rhs_batch: &[usize],
    lhs_contract: &[usize],
    rhs_contract: &[usize],
    tracker: &MemoryTracker,
) -> Tensor {
    let tr = Some(tracker.clone());
    let lhs_free: Vec<usize> = (0..a.rank())
        .filter(|d| !lhs_batch.contains(d) && !lhs_contract.contains(d))
        .collect();
    let rhs_free: Vec<usize> = (0..b.rank())
        .filter(|d| !rhs_batch.contains(d) && !rhs_contract.contains(d))
        .collect();

    let batch: usize = lhs_batch.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let m: usize = lhs_free.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let k: usize = lhs_contract.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let n: usize = rhs_free.iter().map(|&d| b.shape()[d]).product::<usize>().max(1);

    let mut a_perm = lhs_batch.to_vec();
    a_perm.extend(&lhs_free);
    a_perm.extend(lhs_contract);
    let mut b_perm = rhs_batch.to_vec();
    b_perm.extend(rhs_contract);
    b_perm.extend(&rhs_free);

    let a3 = a.permute(&a_perm).reshape(&[batch, m, k], tr.clone());
    let b3 = b.permute(&b_perm).reshape(&[batch, k, n], tr.clone());
    let c3 = matmul(&a3, &b3, tr.clone());

    // Output shape: batch dims, lhs free dims, rhs free dims.
    let mut out_shape: Vec<usize> = lhs_batch.iter().map(|&d| a.shape()[d]).collect();
    out_shape.extend(lhs_free.iter().map(|&d| a.shape()[d]));
    out_shape.extend(rhs_free.iter().map(|&d| b.shape()[d]));
    c3.reshape(&out_shape, tr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{random_inputs, random_params};
    use crate::ir::GraphBuilder;
    use crate::tensor::ops::{BinaryOp, UnaryOp};
    use crate::tensor::reduce::ReduceOp;

    #[test]
    fn mlp_executes_correctly() {
        let mut b = GraphBuilder::new("mlp");
        let x = b.input("x", &[4, 8]);
        let w1 = b.param("w1", &[8, 16]);
        let b1 = b.param("b1", &[16]);
        let h = b.linear(x, w1, b1);
        let a = b.unary(UnaryOp::Relu, h);
        let g = b.finish(vec![a]);

        let tracker = MemoryTracker::new();
        let xs = Tensor::full(1.0, &[4, 8], Some(tracker.clone()));
        let w = Tensor::full(0.5, &[8, 16], None);
        let bias = Tensor::full(-2.0, &[16], None);
        let (outs, stats) = execute(&g, &[xs], &[w, bias], &tracker);
        // 8 * 0.5 - 2 = 2, relu(2) = 2
        assert!(outs[0].to_vec_f32().iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert!(stats.peak_bytes > 0);
        assert_eq!(stats.nodes_executed, 3); // matmul, add, relu
    }

    #[test]
    fn liveness_frees_dead_intermediates() {
        // chain of adds: peak should stay ~2 live tensors, not N.
        let mut b = GraphBuilder::new("chain");
        let x = b.input("x", &[1024]);
        let mut cur = x;
        for _ in 0..32 {
            cur = b.binary_scalar(BinaryOp::Add, cur, 1.0);
        }
        let g = b.finish(vec![cur]);
        let tracker = MemoryTracker::new();
        let xs = Tensor::zeros(&[1024], Some(tracker.clone()));
        let (outs, stats) = execute(&g, &[xs], &[], &tracker);
        assert_eq!(outs[0].to_vec_f32()[0], 32.0);
        // tensor is 4 KiB; peak must be a small multiple, not 32×.
        assert!(
            stats.peak_bytes < 6 * 4096,
            "peak {} suggests liveness is broken",
            stats.peak_bytes
        );
    }

    #[test]
    fn output_kept_alive_despite_zero_users() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let y = b.unary(UnaryOp::Neg, x);
        let g = b.finish(vec![y]);
        let tracker = MemoryTracker::new();
        let xs = Tensor::full(3.0, &[4], Some(tracker.clone()));
        let (outs, _) = execute(&g, &[xs], &[], &tracker);
        assert_eq!(outs[0].to_vec_f32(), vec![-3.0; 4]);
    }

    #[test]
    fn value_used_twice_not_freed_early() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let a = b.unary(UnaryOp::Relu, x);
        let c = b.binary(BinaryOp::Mul, a, a);
        let d = b.binary(BinaryOp::Add, c, a); // a used 3 times total
        let g = b.finish(vec![d]);
        let tracker = MemoryTracker::new();
        let xs = Tensor::full(2.0, &[4], Some(tracker.clone()));
        let (outs, _) = execute(&g, &[xs], &[], &tracker);
        assert_eq!(outs[0].to_vec_f32(), vec![6.0; 4]);
    }

    #[test]
    fn softmax_attention_block() {
        // scaled dot-product attention assembled from primitives
        let (s, d) = (16, 8);
        let mut b = GraphBuilder::new("attn");
        let q = b.input("q", &[s, d]);
        let k = b.input("k", &[s, d]);
        let v = b.input("v", &[s, d]);
        let kt = b.transpose(k, &[1, 0]);
        let scores = b.matmul(q, kt);
        let scaled = b.binary_scalar(BinaryOp::Mul, scores, 1.0 / (d as f32).sqrt());
        let probs = b.softmax(scaled, 1);
        let out = b.matmul(probs, v);
        let g = b.finish(vec![out]);

        let tracker = MemoryTracker::new();
        let ins = random_inputs(&g, 7, Some(tracker.clone()));
        let (outs, _) = execute(&g, &ins, &[], &tracker);
        assert_eq!(outs[0].shape(), &[s, d]);
        // attention outputs are convex combos of V rows: bounded by V range
        let vmax = ins[2].to_vec_f32().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(outs[0].to_vec_f32().iter().all(|&x| x.abs() <= vmax + 1e-5));
    }

    #[test]
    fn dot_general_matches_matmul() {
        let a = Tensor::rand(&[3, 4], 1.0, 1, None);
        let b = Tensor::rand(&[4, 5], 1.0, 2, None);
        let tracker = MemoryTracker::new();
        let dg = dot_general(&a, &b, &[], &[], &[1], &[0], &tracker);
        let mm = matmul(&a, &b, None);
        assert!(dg.max_abs_diff(&mm) < 1e-5);
    }

    #[test]
    fn dot_general_batched() {
        let a = Tensor::rand(&[2, 3, 4], 1.0, 3, None);
        let b = Tensor::rand(&[2, 4, 5], 1.0, 4, None);
        let tracker = MemoryTracker::new();
        let dg = dot_general(&a, &b, &[0], &[0], &[2], &[1], &tracker);
        let mm = matmul(&a, &b, None);
        assert!(dg.max_abs_diff(&mm) < 1e-5);
    }

    #[test]
    fn gather_and_convert_pipeline() {
        let mut b = GraphBuilder::new("emb");
        let table = b.param("table", &[128, 4]);
        let ids = b.input_i32("ids", &[2, 3]);
        let e = b.gather(table, ids);
        let r = b.reduce(ReduceOp::Sum, e, 2, false);
        let g = b.finish(vec![r]);
        let tracker = MemoryTracker::new();
        let ins = random_inputs(&g, 11, Some(tracker.clone()));
        let ps = random_params(&g, 5);
        let (outs, _) = execute(&g, &ins, &ps, &tracker);
        assert_eq!(outs[0].shape(), &[2, 3]);
    }

    #[test]
    fn params_do_not_count_as_activation() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 4]);
        let w = b.param("w", &[4, 4]);
        let y = b.matmul(x, w);
        let g = b.finish(vec![y]);
        let tracker = MemoryTracker::new();
        let xs = Tensor::zeros(&[4, 4], Some(tracker.clone()));
        let ws = Tensor::zeros(&[4, 4], None); // untracked
        let (_, stats) = execute(&g, &[xs], &[ws], &tracker);
        // peak = input + output (+small workspace), strictly less than
        // if the weight had been tracked too.
        assert!(stats.peak_bytes <= 3 * 64);
    }
}
