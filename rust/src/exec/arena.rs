//! Planned-allocation arena executor (DESIGN.md §12).
//!
//! Runs a graph by following the static memory planner's script
//! ([`MemPlan`], `passes::memplan`): every materialized intermediate is
//! written into a pre-assigned arena slot (recycled storage — no per-op
//! allocation on the hot path), views alias their producer's storage, and
//! eligible elementwise ops compute in place into their dead operand.
//! Values are dropped exactly where the planner's release lists say, so
//! the runtime [`Arena`] high-water mark equals `planned_peak_bytes`
//! *exactly* — admission control can price requests with the planner's
//! number instead of the pessimistic quote.
//!
//! Chunked execution mirrors `plan::exec_chunked`: regions fire at the
//! same trigger points, outputs accumulate into planned outer-arena
//! slots, and every concurrent chunk lane gets its own disjoint sub-arena
//! built from the region's lane plan — the concurrency governor's degree
//! math is exact because one extra lane costs exactly `lane_admission`
//! bytes. Results are bitwise identical to the interpreter at any pool
//! width: the kernels' `_into` cores are the same code the allocating
//! wrappers run.

use crate::exec::ExecStats;
use crate::ir::{Graph, Node, Op};
use crate::passes::memplan::{MemPlan, RegionMemPlan, SpillKind, ValueAction};
use crate::plan::exec_chunked::{adjust_node, governed_degree, ExecOptions};
use crate::plan::{region_owner, region_triggers, ChunkPlan};
use crate::tensor::attention::{fused_attention_into, fused_attention_pos_into};
use crate::tensor::conv::{avgpool2x_into, conv2d_into};
use crate::tensor::layout::{concat_into, concat_shape, gather_rows_into, upsample2x_into};
use crate::tensor::matmul::matmul_into;
use crate::tensor::ops::{binary_inplace, binary_into, to_f32_into, unary_inplace, unary_into};
use crate::tensor::reduce::{reduce_into, softmax_into};
use crate::tensor::{
    broadcast_shapes, contiguous_strides, numel, Arena, ArenaStore, DType, MemoryTracker,
    SpillStore, Tensor,
};
use crate::util::pool;
use std::collections::HashMap;

/// Recycled slot storage for every arena a memory plan spawns: the outer
/// arena plus one store per chunk region, shared by all of that region's
/// concurrent lanes. Cached on the `PlanHandle` so warmed re-runs —
/// chunked or not — perform zero fresh allocations.
#[derive(Clone, Debug)]
pub struct ArenaStores {
    pub outer: ArenaStore,
    /// Parallel to `MemPlan::regions`; lanes of one region share a store
    /// (concurrent lanes pop distinct cached storage or allocate fresh).
    pub lanes: Vec<ArenaStore>,
    /// Slow-tier byte accounting for the plan's spill/restore script.
    /// Cold (all-zero) unless the plan carries spill decisions.
    pub spill: SpillStore,
}

impl ArenaStores {
    pub fn for_plan(mem: &MemPlan) -> ArenaStores {
        ArenaStores {
            outer: ArenaStore::new(mem.slots.len()),
            lanes: mem.regions.iter().map(|r| ArenaStore::new(r.slots.len())).collect(),
            spill: SpillStore::new(),
        }
    }

    /// Fresh backing allocations across the outer and all lane stores.
    pub fn fresh_allocs(&self) -> usize {
        self.outer.fresh_allocs() + self.lanes.iter().map(|s| s.fresh_allocs()).sum::<usize>()
    }

    /// Cache-served acquires across the outer and all lane stores.
    pub fn reuses(&self) -> usize {
        self.outer.reuses() + self.lanes.iter().map(|s| s.reuses()).sum::<usize>()
    }
}

/// Execute `graph` under `plans` (empty = unchunked) following the memory
/// plan `mem`. `stores` optionally supplies recycled slot storage from a
/// previous run of the same plan (the serving hot path). Semantics and
/// results are bitwise identical to [`crate::exec::execute`] /
/// [`crate::plan::execute_chunked`].
#[allow(clippy::too_many_arguments)]
pub fn execute_arena(
    graph: &Graph,
    plans: &[ChunkPlan],
    inputs: &[Tensor],
    params: &[Tensor],
    mem: &MemPlan,
    stores: Option<&ArenaStores>,
    tracker: &MemoryTracker,
    opts: &ExecOptions,
) -> (Vec<Tensor>, ExecStats) {
    assert_eq!(inputs.len(), graph.inputs.len(), "input arity");
    assert_eq!(params.len(), graph.params.len(), "param arity");
    assert_eq!(mem.actions.len(), graph.len(), "plan/graph arity");
    assert_eq!(mem.regions.len(), plans.len(), "plan/regions arity");

    if let Some(fs) = &opts.faults {
        // Injected arena-allocation failure: unwind before the run's
        // arena hands out its first slot, so `live`/high-water
        // accounting and the shared store cannot leak across the panic.
        fs.trip(crate::util::fault::FaultSite::ArenaAlloc);
    }

    let fresh_stores;
    let stores = match stores {
        Some(s) => s,
        None => {
            fresh_stores = ArenaStores::for_plan(mem);
            &fresh_stores
        }
    };
    let arena = Arena::with_store(mem.slots.clone(), stores.outer.clone());

    let owner = region_owner(plans, graph.len());
    let triggers = region_triggers(plans);

    let mut values: Vec<Option<Tensor>> = vec![None; graph.len()];
    for (pos, &id) in graph.inputs.iter().enumerate() {
        assert_eq!(
            inputs[pos].shape(),
            graph.node(id).shape.as_slice(),
            "input {pos} shape mismatch"
        );
        values[id] = Some(inputs[pos].clone());
    }
    for (pos, &id) in graph.params.iter().enumerate() {
        assert_eq!(
            params[pos].shape(),
            graph.node(id).shape.as_slice(),
            "param {pos} shape mismatch"
        );
        values[id] = Some(params[pos].clone());
    }
    let prebound: Vec<bool> = {
        let mut v = vec![false; graph.len()];
        for &i in graph.inputs.iter().chain(graph.params.iter()) {
            v[i] = true;
        }
        v
    };

    let mut stats = ExecStats {
        threads: pool::num_threads(),
        ..ExecStats::default()
    };

    // Spill/restore script (cold unless the planner accepted placement
    // decisions): restores run at the top of their position, spills at
    // its very end — exactly the splice points the planner's replay
    // priced, which keeps high-water == planned_peak_bytes exact.
    let mut restore_at: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut spill_at: HashMap<usize, Vec<usize>> = HashMap::new();
    let mut stash: Vec<Option<(Vec<f32>, Vec<usize>)>> = Vec::new();
    if !mem.spills.is_empty() {
        stash.resize_with(mem.spills.len(), || None);
        for (di, d) in mem.spills.iter().enumerate() {
            restore_at.entry(d.restore_before).or_default().push(di);
            spill_at.entry(d.spill_after).or_default().push(di);
        }
    }

    for node in &graph.nodes {
        let id = node.id;
        if !mem.spills.is_empty() {
            if let Some(dis) = restore_at.get(&id) {
                for &di in dis {
                    let d = &mem.spills[di];
                    match d.kind {
                        SpillKind::Offload => {
                            let (data, shape) =
                                stash[di].take().expect("restore before spill in script");
                            let mut buf = arena.acquire_f32(d.slot, data.len());
                            buf.copy_from_slice(&data);
                            values[d.value] = Some(Tensor::from_arena_f32(
                                buf,
                                &shape,
                                &arena,
                                d.slot,
                                Some(tracker.clone()),
                            ));
                            stores.spill.on_restore(data.len() * 4);
                            stats.spill_in_bytes += data.len() * 4;
                            if let Some(ts) = &opts.trace {
                                use crate::util::trace::ArgV;
                                ts.instant(
                                    "spill.in",
                                    vec![
                                        ("value", ArgV::U(d.value as u64)),
                                        ("bytes", ArgV::U((data.len() * 4) as u64)),
                                    ],
                                );
                            }
                        }
                        SpillKind::Recompute => {
                            // Same `_into` kernel over the same live
                            // inputs: bitwise identical to the original.
                            let src = graph.node(d.value);
                            let out = exec_materialize(src, d.slot, &values, &arena, tracker);
                            values[d.value] = Some(out);
                            stats.spill_recomputes += 1;
                            if let Some(ts) = &opts.trace {
                                use crate::util::trace::ArgV;
                                ts.instant(
                                    "spill.recompute",
                                    vec![("value", ArgV::U(d.value as u64))],
                                );
                            }
                        }
                    }
                    stats.spill_events += 1;
                }
            }
        }
        let skip = prebound[id] || owner[id].is_some();
        if !skip {
            let out = match &opts.trace {
                Some(ts) => {
                    let sp = ts.begin();
                    let out = exec_node_arena(node, mem.actions[id], &mut values, &arena, tracker);
                    ts.end(
                        sp,
                        &node.op.mnemonic(),
                        vec![("node", crate::util::trace::ArgV::U(id as u64))],
                    );
                    out
                }
                None => exec_node_arena(node, mem.actions[id], &mut values, &arena, tracker),
            };
            stats.nodes_executed += 1;
            values[id] = Some(out);
            // Node-phase releases, exactly where the planner freed.
            for &v in &mem.release_after[id] {
                values[v] = None;
            }
        }
        // Fire regions triggered at this id (same schedule as the
        // chunked interpreter).
        if let Some(plan_ids) = triggers.get(&id) {
            for &pi in plan_ids {
                execute_region_arena(
                    graph,
                    &plans[pi],
                    &mem.regions[pi],
                    mem,
                    &mut values,
                    &arena,
                    &stores.lanes[pi],
                    tracker,
                    opts,
                    &mut stats,
                );
                for &v in &mem.regions[pi].post_releases {
                    values[v] = None;
                }
            }
        }
        if !mem.spills.is_empty() {
            if let Some(dis) = spill_at.get(&id) {
                for &di in dis {
                    let d = &mem.spills[di];
                    let t = values[d.value]
                        .take()
                        .unwrap_or_else(|| panic!("spill of dead value {}", d.value));
                    if d.kind == SpillKind::Offload {
                        let data = t.to_vec_f32();
                        let shape = t.shape().to_vec();
                        stores.spill.on_spill(data.len() * 4);
                        stats.spill_out_bytes += data.len() * 4;
                        if let Some(ts) = &opts.trace {
                            use crate::util::trace::ArgV;
                            ts.instant(
                                "spill.out",
                                vec![
                                    ("value", ArgV::U(d.value as u64)),
                                    ("bytes", ArgV::U((data.len() * 4) as u64)),
                                ],
                            );
                        }
                        stash[di] = Some((data, shape));
                    }
                    stats.spill_events += 1;
                    drop(t); // sole owner: frees the arena slot bytes now
                }
            }
        }
    }

    let outputs: Vec<Tensor> = graph
        .outputs
        .iter()
        .map(|&o| values[o].clone().expect("output not computed"))
        .collect();
    stats.peak_bytes = tracker.peak();
    stats.arena_peak_bytes = arena.high_water();
    // Per-run arena counters (lane traffic was added by each region):
    // concurrent runs over the same shared stores stay correctly
    // attributed because these live on the run's arenas, not the store.
    stats.arena_fresh_allocs += arena.fresh_allocs();
    stats.arena_reuses += arena.reuses();
    (outputs, stats)
}

/// Execute one node per its planned action. `node` may be a
/// chunk-adjusted clone inside region lanes; all materialize sizes derive
/// from the *actual* input tensors so short chunk tails stay correct.
fn exec_node_arena(
    node: &Node,
    action: ValueAction,
    values: &mut [Option<Tensor>],
    arena: &Arena,
    tracker: &MemoryTracker,
) -> Tensor {
    match action {
        ValueAction::Alias => exec_alias(node, values),
        ValueAction::Materialize { slot } => exec_materialize(node, slot, values, arena, tracker),
        ValueAction::InPlace { pos } => exec_inplace(node, pos, values),
        ValueAction::External | ValueAction::Region => {
            unreachable!("action {action:?} is not executable for node {}", node.id)
        }
    }
}

/// Zero-copy view actions.
fn exec_alias(node: &Node, values: &[Option<Tensor>]) -> Tensor {
    let arg = |i: usize| -> &Tensor {
        values[node.inputs[i]]
            .as_ref()
            .unwrap_or_else(|| panic!("value {} not live for node {}", node.inputs[i], node.id))
    };
    match &node.op {
        Op::Transpose { perm } => arg(0).permute(perm),
        Op::Slice { axis, start, len } => arg(0).slice_axis(*axis, *start, *len),
        Op::Reshape => {
            let a = arg(0);
            debug_assert!(a.is_contiguous(), "planner aliased a copying reshape");
            a.reshape(&node.shape, None)
        }
        Op::Convert => {
            let a = arg(0);
            debug_assert!(
                a.dtype() == DType::F32 && a.is_contiguous(),
                "planner aliased a copying convert"
            );
            a.clone()
        }
        Op::Broadcast { dims } => {
            let a = arg(0);
            debug_assert!(a.is_contiguous(), "planner aliased a copying broadcast");
            let mut reshaped = vec![1usize; node.shape.len()];
            for (i, &d) in dims.iter().enumerate() {
                reshaped[d] = a.shape()[i];
            }
            a.reshape(&reshaped, None).broadcast_to(&node.shape)
        }
        other => unreachable!("op {} cannot alias", other.mnemonic()),
    }
}

/// Elementwise op computed into its dead operand's slot storage. The
/// output shape is the operand's *actual* shape (equal to the op's output
/// shape by the planner's eligibility rule), which stays correct for
/// short chunk-tail iterations where `node.shape` is the full extent.
fn exec_inplace(node: &Node, pos: usize, values: &mut [Option<Tensor>]) -> Tensor {
    let target_id = node.inputs[pos];
    let t = values[target_id]
        .take()
        .unwrap_or_else(|| panic!("in-place operand {target_id} not live for node {}", node.id));
    let shape = t.shape().to_vec();
    let (mut v, arena, slot, tr) = t.try_take_arena_f32().unwrap_or_else(|_| {
        panic!(
            "planner authorized in-place for node {} but operand {target_id} has live references",
            node.id
        )
    });
    match &node.op {
        Op::Unary(op) => unary_inplace(*op, &mut v),
        Op::Binary(op) => {
            if node.inputs[0] == node.inputs[1] {
                binary_inplace(*op, &mut v, &shape, true, None);
            } else {
                let other_id = node.inputs[1 - pos];
                let other = values[other_id]
                    .as_ref()
                    .unwrap_or_else(|| panic!("value {other_id} not live for node {}", node.id))
                    .clone();
                binary_inplace(*op, &mut v, &shape, pos == 0, Some(&other));
            }
        }
        other => unreachable!("op {} cannot run in place", other.mnemonic()),
    }
    Tensor::adopt_arena_f32(v, &shape, arena, slot, tr)
}

/// Materializing ops: acquire the planned slot and run the kernel's
/// `_into` core against it.
fn exec_materialize(
    node: &Node,
    slot: usize,
    values: &[Option<Tensor>],
    arena: &Arena,
    tracker: &MemoryTracker,
) -> Tensor {
    let tr = Some(tracker.clone());
    let arg = |i: usize| -> &Tensor {
        values[node.inputs[i]]
            .as_ref()
            .unwrap_or_else(|| panic!("value {} not live for node {}", node.inputs[i], node.id))
    };
    match &node.op {
        Op::Input | Op::Param => unreachable!("leaves are pre-bound"),
        Op::Const(v) => {
            let mut buf = arena.acquire_f32(slot, numel(&node.shape));
            for x in buf.iter_mut() {
                *x = *v;
            }
            Tensor::from_arena_f32(buf, &node.shape, arena, slot, tr)
        }
        Op::Iota { axis } => {
            let n = numel(&node.shape);
            let strides = contiguous_strides(&node.shape);
            let mut buf = arena.acquire_f32(slot, n);
            for (i, x) in buf.iter_mut().enumerate() {
                let idx = (i as isize / strides[*axis]) as usize % node.shape[*axis];
                *x = idx as f32;
            }
            Tensor::from_arena_f32(buf, &node.shape, arena, slot, tr)
        }
        Op::Binary(op) => {
            let n = numel(&broadcast_shapes(arg(0).shape(), arg(1).shape()));
            let mut buf = arena.acquire_f32(slot, n);
            let shape = binary_into(*op, arg(0), arg(1), &mut buf);
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Unary(op) => {
            let a = arg(0);
            let mut buf = arena.acquire_f32(slot, a.numel());
            unary_into(*op, a, &mut buf);
            Tensor::from_arena_f32(buf, a.shape(), arena, slot, tr)
        }
        Op::MatMul => {
            let (a, b) = (arg(0), arg(1));
            let m = a.shape()[a.rank() - 2];
            let n = b.shape()[b.rank() - 1];
            let batch: usize =
                broadcast_shapes(&a.shape()[..a.rank() - 2], &b.shape()[..b.rank() - 2])
                    .iter()
                    .product::<usize>()
                    .max(1);
            let mut buf = arena.acquire_f32(slot, batch * m * n);
            let shape = matmul_into(a, b, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::DotGeneral {
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
        } => dot_general_arena(
            arg(0),
            arg(1),
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
            arena,
            slot,
            tracker,
        ),
        Op::Reshape => {
            let a = arg(0);
            match a.dtype() {
                DType::F32 => {
                    let mut buf = arena.acquire_f32(slot, a.numel());
                    a.copy_into_f32(&mut buf);
                    Tensor::from_arena_f32(buf, &node.shape, arena, slot, tr)
                }
                DType::I32 => {
                    let mut buf = arena.acquire_i32(slot, a.numel());
                    a.copy_into_i32(&mut buf);
                    Tensor::from_arena_i32(buf, &node.shape, arena, slot, tr)
                }
            }
        }
        Op::Broadcast { dims } => {
            // Non-contiguous input: materialize the reshaped copy into
            // the slot, then broadcast the view (stride-0 dims).
            let a = arg(0);
            let mut reshaped = vec![1usize; node.shape.len()];
            for (i, &d) in dims.iter().enumerate() {
                reshaped[d] = a.shape()[i];
            }
            let base = match a.dtype() {
                DType::F32 => {
                    let mut buf = arena.acquire_f32(slot, a.numel());
                    a.copy_into_f32(&mut buf);
                    Tensor::from_arena_f32(buf, &reshaped, arena, slot, tr)
                }
                DType::I32 => {
                    let mut buf = arena.acquire_i32(slot, a.numel());
                    a.copy_into_i32(&mut buf);
                    Tensor::from_arena_i32(buf, &reshaped, arena, slot, tr)
                }
            };
            base.broadcast_to(&node.shape)
        }
        Op::Reduce { op, axis, keepdims } => {
            let a = arg(0);
            let rows = a.numel() / a.shape()[*axis];
            let mut buf = arena.acquire_f32(slot, rows);
            let shape = reduce_into(*op, a, *axis, *keepdims, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Softmax { axis } => {
            let a = arg(0);
            let mut buf = arena.acquire_f32(slot, a.numel());
            softmax_into(a, *axis, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, a.shape(), arena, slot, tr)
        }
        Op::Concat { axis } => {
            let parts: Vec<Tensor> = node
                .inputs
                .iter()
                .map(|&i| values[i].clone().expect("concat part not live"))
                .collect();
            let shape = concat_shape(&parts, *axis);
            let mut buf = arena.acquire_f32(slot, numel(&shape));
            let shape = concat_into(&parts, *axis, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Gather => {
            let (table, ids) = (arg(0), arg(1));
            let d = table.shape()[1];
            let mut buf = arena.acquire_f32(slot, ids.numel() * d);
            let shape = gather_rows_into(table, ids, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Conv2d { stride, pad } => {
            let (x, w) = (arg(0), arg(1));
            let (h, wd) = (x.shape()[2], x.shape()[3]);
            let (cout, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
            let ho = (h + 2 * pad - kh) / stride + 1;
            let wo = (wd + 2 * pad - kw) / stride + 1;
            let mut buf = arena.acquire_f32(slot, x.shape()[0] * cout * ho * wo);
            let shape = conv2d_into(x, w, *stride, *pad, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::AvgPool2x => {
            let x = arg(0);
            let mut buf = arena.acquire_f32(slot, x.numel() / 4);
            let shape = avgpool2x_into(x, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Upsample2x => {
            let x = arg(0);
            let mut buf = arena.acquire_f32(slot, x.numel() * 4);
            let shape = upsample2x_into(x, &mut buf, tr.clone());
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Convert => {
            let a = arg(0);
            let mut buf = arena.acquire_f32(slot, a.numel());
            to_f32_into(a, &mut buf);
            Tensor::from_arena_f32(buf, a.shape(), arena, slot, tr)
        }
        Op::FusedAttention { scale } => {
            let (q, k, v) = (arg(0), arg(1), arg(2));
            let sq = q.shape()[q.rank() - 2];
            let dv = v.shape()[v.rank() - 1];
            let batch: usize = broadcast_shapes(
                &broadcast_shapes(&q.shape()[..q.rank() - 2], &k.shape()[..k.rank() - 2]),
                &v.shape()[..v.rank() - 2],
            )
            .iter()
            .product::<usize>()
            .max(1);
            let mut buf = arena.acquire_f32(slot, batch * sq * dv);
            let shape = if node.inputs.len() > 3 {
                fused_attention_pos_into(q, k, v, arg(3), *scale, &mut buf, tr.clone())
            } else {
                fused_attention_into(q, k, v, *scale, &mut buf, tr.clone())
            };
            Tensor::from_arena_f32(buf, &shape, arena, slot, tr)
        }
        Op::Transpose { .. } | Op::Slice { .. } => {
            unreachable!("views never materialize (node {})", node.id)
        }
        Op::Opaque { kind } => panic!("opaque op '{kind}' is analysis-only (execute via PJRT)"),
    }
}

/// General dot canonicalized to batched matmul, writing the GEMM straight
/// into the planned slot (the trailing reshape is a zero-copy view of the
/// same arena buffer). Mirrors the interpreter's `dot_general`.
#[allow(clippy::too_many_arguments)]
fn dot_general_arena(
    a: &Tensor,
    b: &Tensor,
    lhs_batch: &[usize],
    rhs_batch: &[usize],
    lhs_contract: &[usize],
    rhs_contract: &[usize],
    arena: &Arena,
    slot: usize,
    tracker: &MemoryTracker,
) -> Tensor {
    let tr = Some(tracker.clone());
    let lhs_free: Vec<usize> = (0..a.rank())
        .filter(|d| !lhs_batch.contains(d) && !lhs_contract.contains(d))
        .collect();
    let rhs_free: Vec<usize> = (0..b.rank())
        .filter(|d| !rhs_batch.contains(d) && !rhs_contract.contains(d))
        .collect();

    let batch: usize = lhs_batch.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let m: usize = lhs_free.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let k: usize = lhs_contract.iter().map(|&d| a.shape()[d]).product::<usize>().max(1);
    let n: usize = rhs_free.iter().map(|&d| b.shape()[d]).product::<usize>().max(1);

    let mut a_perm = lhs_batch.to_vec();
    a_perm.extend(&lhs_free);
    a_perm.extend(lhs_contract);
    let mut b_perm = rhs_batch.to_vec();
    b_perm.extend(rhs_contract);
    b_perm.extend(&rhs_free);

    let a3 = a.permute(&a_perm).reshape(&[batch, m, k], tr.clone());
    let b3 = b.permute(&b_perm).reshape(&[batch, k, n], tr.clone());

    let mut buf = arena.acquire_f32(slot, batch * m * n);
    let c_shape = matmul_into(&a3, &b3, &mut buf, tr.clone());
    let c3 = Tensor::from_arena_f32(buf, &c_shape, arena, slot, tr);

    // Output shape: batch dims, lhs free dims, rhs free dims.
    let mut out_shape: Vec<usize> = lhs_batch.iter().map(|&d| a.shape()[d]).collect();
    out_shape.extend(lhs_free.iter().map(|&d| a.shape()[d]));
    out_shape.extend(rhs_free.iter().map(|&d| b.shape()[d]));
    c3.reshape(&out_shape, None)
}

/// Output accumulator backed by a planned outer-arena slot.
struct ArenaAccumulator {
    data: Vec<f32>,
    shape: Vec<usize>,
    axis: usize,
    filled: usize,
    slot: usize,
}

impl ArenaAccumulator {
    fn new(shape: &[usize], axis: usize, arena: &Arena, slot: usize) -> Self {
        let data = arena.acquire_f32(slot, crate::tensor::numel(shape));
        ArenaAccumulator {
            data,
            shape: shape.to_vec(),
            axis,
            filled: 0,
            slot,
        }
    }

    /// Copy `part` (a chunk of the output along `axis`) into place —
    /// same layout math as the interpreter's accumulator.
    fn push(&mut self, part: &Tensor, tracker: &MemoryTracker) {
        let part = part.to_contiguous(Some(tracker.clone()));
        let src = part.f32_contiguous();
        let axis = self.axis;
        let inner: usize = self.shape[axis + 1..].iter().product();
        let outer: usize = self.shape[..axis].iter().product();
        let out_slab = self.shape[axis] * inner;
        let p_axis = part.shape()[axis];
        let run = p_axis * inner;
        for o in 0..outer.max(1) {
            let dst = o * out_slab + self.filled * inner;
            self.data[dst..dst + run].copy_from_slice(&src[o * run..(o + 1) * run]);
        }
        self.filled += p_axis;
    }

    fn finish(self, arena: &Arena, tracker: &MemoryTracker) -> Tensor {
        assert_eq!(self.filled, self.shape[self.axis], "accumulator underfilled");
        Tensor::from_arena_f32(
            self.data,
            &self.shape,
            arena,
            self.slot,
            Some(tracker.clone()),
        )
    }
}

/// Run one region's chunk loop with planned memory: accumulators and
/// pass-input copies in the outer arena, per-lane sub-arenas for the
/// iteration bodies, degree from the exact lane price.
#[allow(clippy::too_many_arguments)]
fn execute_region_arena(
    graph: &Graph,
    plan: &ChunkPlan,
    region: &RegionMemPlan,
    mem: &MemPlan,
    values: &mut [Option<Tensor>],
    outer_arena: &Arena,
    lane_store: &ArenaStore,
    tracker: &MemoryTracker,
    opts: &ExecOptions,
    stats: &mut ExecStats,
) {
    let extent = plan.chunk_extent(graph);
    let step = plan.chunk_step(graph);
    let mut iters: Vec<(usize, usize)> = Vec::new();
    let mut start = 0usize;
    while start < extent {
        let len = step.min(extent - start);
        iters.push((start, len));
        start += len;
    }

    // Exact degree math: the serial planned price plus one lane_admission
    // per extra in-flight iteration.
    let degree = governed_degree(
        pool::num_threads(),
        iters.len(),
        opts.budget_bytes,
        mem.admission_base,
        region.lane_admission,
    );
    stats.max_chunk_degree = stats.max_chunk_degree.max(degree);

    // Pass-input copies (planned outer slots; `None` = pass as-is).
    let pass_vals: Vec<Tensor> = plan
        .pass_inputs
        .iter()
        .zip(&region.pass_slots)
        .map(|(&p, slot)| {
            let v = values[p].as_ref().expect("pass input not live");
            match slot {
                None => v.clone(),
                Some(s) => match v.dtype() {
                    DType::F32 => {
                        let mut buf = outer_arena.acquire_f32(*s, v.numel());
                        v.copy_into_f32(&mut buf);
                        Tensor::from_arena_f32(
                            buf,
                            v.shape(),
                            outer_arena,
                            *s,
                            Some(tracker.clone()),
                        )
                    }
                    DType::I32 => {
                        let mut buf = outer_arena.acquire_i32(*s, v.numel());
                        v.copy_into_i32(&mut buf);
                        Tensor::from_arena_i32(
                            buf,
                            v.shape(),
                            outer_arena,
                            *s,
                            Some(tracker.clone()),
                        )
                    }
                },
            }
        })
        .collect();

    // Output accumulators in their planned outer slots.
    let mut accs: Vec<ArenaAccumulator> = plan
        .outputs
        .iter()
        .zip(&region.accum_slots)
        .map(|(&(o, axis), &slot)| {
            ArenaAccumulator::new(&graph.node(o).shape, axis, outer_arena, slot)
        })
        .collect();

    // One sub-arena per concurrent lane over the region's shared store:
    // storage recycles across waves within the run and across runs of
    // the same plan handle.
    let lane_arenas: Vec<Arena> = (0..degree.max(1))
        .map(|_| Arena::with_store(region.slots.clone(), lane_store.clone()))
        .collect();

    // Chunk sub-lanes are keyed by iteration ordinal (never the lane
    // slot) and this firing's derive-block, so the trace is identical at
    // any governed degree (DESIGN.md §19).
    let tr = opts.trace.as_ref().map(|t| (t, t.derive_block()));
    let chunk_span = |iter: usize| {
        tr.map(|(t, block)| {
            let cs = t.child(crate::util::trace::chunk_lane(t.lane(), iter), block << 32);
            let sp = cs.begin();
            (cs, sp)
        })
    };
    let chunk_close = |csp: Option<(crate::util::trace::TraceScope, crate::util::trace::SpanStart)>,
                       iter: usize,
                       start: usize,
                       len: usize| {
        if let Some((cs, sp)) = csp {
            use crate::util::trace::ArgV;
            cs.end(
                sp,
                "chunk",
                vec![
                    ("iter", ArgV::U(iter as u64)),
                    ("start", ArgV::U(start as u64)),
                    ("len", ArgV::U(len as u64)),
                ],
            );
        }
    };

    if degree <= 1 {
        for (iter, &(start, len)) in iters.iter().enumerate() {
            let csp = chunk_span(iter);
            let outs = run_lane_iteration(
                graph,
                plan,
                region,
                values,
                &pass_vals,
                &lane_arenas[0],
                tracker,
                start,
                len,
            );
            chunk_close(csp, iter, start, len);
            stats.nodes_executed += plan.region.len();
            for (k, t) in outs.into_iter().enumerate() {
                accs[k].push(&t, tracker);
            }
        }
    } else {
        let values_ro: &[Option<Tensor>] = values;
        for (wslot, wave) in iters.chunks(degree).enumerate() {
            let results: Vec<Vec<Tensor>> = pool::parallel_map(wave.len(), |wi| {
                let (start, len) = wave[wi];
                // global iteration ordinal, matching the serial path
                let iter = wslot * degree + wi;
                let csp = chunk_span(iter);
                let outs = run_lane_iteration(
                    graph,
                    plan,
                    region,
                    values_ro,
                    &pass_vals,
                    &lane_arenas[wi],
                    tracker,
                    start,
                    len,
                );
                chunk_close(csp, iter, start, len);
                outs
            });
            stats.nodes_executed += plan.region.len() * wave.len();
            for outs in results {
                for (k, t) in outs.into_iter().enumerate() {
                    accs[k].push(&t, tracker);
                }
            }
        }
    }

    stats.lane_peak_bytes = stats
        .lane_peak_bytes
        .max(lane_arenas.iter().map(|a| a.high_water()).max().unwrap_or(0));
    stats.arena_fresh_allocs += lane_arenas.iter().map(|a| a.fresh_allocs()).sum::<usize>();
    stats.arena_reuses += lane_arenas.iter().map(|a| a.reuses()).sum::<usize>();

    for (&(o, _), acc) in plan.outputs.iter().zip(accs) {
        values[o] = Some(acc.finish(outer_arena, tracker));
    }
}

/// Execute one chunk iteration on a lane sub-arena, returning the output
/// tensors in `plan.outputs` order.
#[allow(clippy::too_many_arguments)]
fn run_lane_iteration(
    graph: &Graph,
    plan: &ChunkPlan,
    region: &RegionMemPlan,
    values_ro: &[Option<Tensor>],
    pass_vals: &[Tensor],
    lane_arena: &Arena,
    tracker: &MemoryTracker,
    start: usize,
    len: usize,
) -> Vec<Tensor> {
    let mut local: Vec<Option<Tensor>> = vec![None; graph.len()];
    for (k, &p) in plan.pass_inputs.iter().enumerate() {
        local[p] = Some(pass_vals[k].clone());
    }
    for &(i, axis) in &plan.chunk_inputs {
        let base = values_ro[i].as_ref().expect("chunk input not live");
        local[i] = Some(base.slice_axis(axis, start, len));
    }
    for (k, &(r, action)) in region.actions.iter().enumerate() {
        let node = graph.node(r);
        let adjusted = adjust_node(node, plan.node_dims[&r], len);
        let out = match &adjusted {
            Some(n) => exec_node_arena(n, action, &mut local, lane_arena, tracker),
            None => exec_node_arena(node, action, &mut local, lane_arena, tracker),
        };
        local[r] = Some(out);
        for &v in &region.release_after[k] {
            local[v] = None;
        }
    }
    plan.outputs
        .iter()
        .map(|&(o, _)| local[o].take().expect("region output missing"))
        .collect()
}
