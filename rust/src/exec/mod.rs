//! Graph interpreter with liveness-driven memory accounting.
//!
//! Executes a [`Graph`] node-by-node in topological order. Every
//! intermediate lands on the run's [`MemoryTracker`]; a value is dropped as
//! soon as its last consumer has executed, so the tracker's high-water mark
//! is the *measured* peak activation memory of the execution — the quantity
//! the paper's Figure 1/5/6/7 report from the CUDA allocator.
//!
//! Parameters are allocated untracked (parameter memory is out of scope of
//! activation accounting, Eq. 1). Inputs and outputs are tracked.

pub mod arena;
mod interpreter;

pub use arena::{execute_arena, ArenaStores};
pub use interpreter::{execute, execute_node, execute_traced, ExecStats};

use crate::ir::Graph;
use crate::tensor::{MemoryTracker, Tensor};

/// Deterministically-seeded random parameters for a graph (test/bench aid).
pub fn random_params(graph: &Graph, seed: u64) -> Vec<Tensor> {
    graph
        .params
        .iter()
        .enumerate()
        .map(|(i, &p)| {
            let n = graph.node(p);
            // ~Xavier scale keeps activations O(1) through deep stacks.
            // conv weights are OIHW: fan-in = Cin·Kh·Kw; linear are [in, out].
            let fan_in = match n.shape.len() {
                4 => n.shape[1] * n.shape[2] * n.shape[3],
                _ => n.shape.first().copied().unwrap_or(1),
            }
            .max(1);
            let scale = (1.0 / fan_in as f32).sqrt();
            Tensor::rand(&n.shape, scale, seed.wrapping_add(i as u64), None)
        })
        .collect()
}

/// Deterministically-seeded random inputs, allocated on `tracker`.
pub fn random_inputs(graph: &Graph, seed: u64, tracker: Option<MemoryTracker>) -> Vec<Tensor> {
    graph
        .inputs
        .iter()
        .enumerate()
        .map(|(i, &p)| {
            let n = graph.node(p);
            match n.dtype {
                crate::tensor::DType::F32 => {
                    Tensor::rand(&n.shape, 1.0, seed.wrapping_add(1000 + i as u64), tracker.clone())
                }
                crate::tensor::DType::I32 => {
                    // token-ish ids in [0, 64)
                    let count = crate::tensor::numel(&n.shape);
                    let mut state = seed.wrapping_add(2000 + i as u64).wrapping_mul(0x9E3779B97F4A7C15) | 1;
                    let mut v = Vec::with_capacity(count);
                    for _ in 0..count {
                        state ^= state << 13;
                        state ^= state >> 7;
                        state ^= state << 17;
                        v.push((state % 64) as i32); // vocab >= 64 assumed
                    }
                    Tensor::from_i32(v, &n.shape, tracker.clone())
                }
            }
        })
        .collect()
}
