//! Estimation pass: activation-memory profile of a graph.
//!
//! Simulates the interpreter's allocation behaviour analytically:
//! liveness-driven frees, view aliasing (transpose/slice allocate nothing),
//! and kernel workspace (im2col, softmax/reduce permute copies, matmul
//! broadcast materialization) — the "memory cost due to continuous
//! operation" the paper's §3.4 insists on modelling.
//!
//! The pass yields the per-node live-byte series (Figure 4), the peak and
//! the peak node (the anchor for chunk search), and — via
//! [`estimate_under_plan`] — the same profile under a set of chunk plans,
//! which is what chunk selection iterates against (Eq. 2 semantics).

use crate::ir::{Graph, NodeId, Op};
use crate::plan::{region_owner, ChunkPlan};


/// Result of the estimation pass.
#[derive(Clone, Debug)]
pub struct MemoryProfile {
    /// Live activation bytes at (i.e. just after allocating the output of)
    /// each node, in execution order. Leaves report the live set as-is.
    pub per_node: Vec<usize>,
    /// Peak of `per_node`.
    pub peak_bytes: usize,
    /// Node at which the peak occurs.
    pub peak_node: NodeId,
    /// Bytes of persistent (cross-execution) inputs the graph binds —
    /// excluded from the activation series above; the serving tier prices
    /// them as resident state (KV caches, cached prefixes).
    pub persistent_bytes: usize,
}

impl MemoryProfile {
    /// Fraction of nodes whose live-byte level is below `frac` of peak —
    /// the paper's Figure-4 observation (">70% of nodes under 30% of max").
    pub fn fraction_below(&self, frac: f64) -> f64 {
        if self.per_node.is_empty() {
            return 0.0;
        }
        let cut = self.peak_bytes as f64 * frac;
        let n = self
            .per_node
            .iter()
            .filter(|&&b| (b as f64) < cut)
            .count();
        n as f64 / self.per_node.len() as f64
    }
}

/// Does this op produce a zero-copy view of its input?
fn is_view(op: &Op) -> bool {
    matches!(op, Op::Transpose { .. } | Op::Slice { .. } | Op::Broadcast { .. })
}

/// Contiguity model mirroring the kernels in `crate::tensor`.
fn output_contiguous(graph: &Graph, id: NodeId, contig: &[bool]) -> bool {
    let node = graph.node(id);
    match &node.op {
        Op::Transpose { perm } => {
            // identity permutation stays contiguous
            perm.iter().enumerate().all(|(i, &p)| i == p) && contig[node.inputs[0]]
        }
        Op::Slice { axis, .. } => *axis == 0 && contig[node.inputs[0]],
        Op::Broadcast { .. } => {
            // stride-0 dims unless shape is unchanged
            graph.node(node.inputs[0]).shape == node.shape && contig[node.inputs[0]]
        }
        // every computing/materializing op emits contiguous data
        _ => true,
    }
}

/// Transient workspace bytes a node's kernel allocates beyond its output,
/// given per-input contiguity. Mirrors `crate::tensor` kernel behaviour.
fn node_workspace(graph: &Graph, id: NodeId, contig: &[bool]) -> usize {
    let node = graph.node(id);
    let in_bytes = |i: usize| -> usize { graph.node(node.inputs[i]).byte_size() };
    match &node.op {
        Op::MatMul | Op::DotGeneral { .. } => {
            // non-contiguous operands are materialized; batch broadcasting
            // additionally expands to the full batch.
            let mut ws = 0;
            for (pos, &inp) in node.inputs.iter().enumerate() {
                let b = in_bytes(pos);
                if !contig[inp] {
                    ws += b;
                }
            }
            ws
        }
        Op::Reshape => 0, // copy counted as the output when non-contig input
        Op::Reduce { axis, .. } => {
            // permute+materialize when the reduce axis is not innermost
            let rank = graph.node(node.inputs[0]).shape.len();
            if *axis != rank - 1 || !contig[node.inputs[0]] {
                in_bytes(0)
            } else {
                0
            }
        }
        Op::Softmax { axis } => {
            let rank = graph.node(node.inputs[0]).shape.len();
            if *axis != rank - 1 || !contig[node.inputs[0]] {
                // permuted copy in + permuted copy out
                2 * in_bytes(0)
            } else {
                0
            }
        }
        Op::Concat { .. } => {
            // non-contiguous parts are materialized before the copy
            node.inputs
                .iter()
                .enumerate()
                .filter(|&(_, &i)| !contig[i])
                .map(|(pos, _)| in_bytes(pos))
                .sum()
        }
        Op::Conv2d { .. } => {
            // im2col matrix [N*Ho*Wo, Cin*Kh*Kw] + pre-permute NHWC output
            let w = &graph.node(node.inputs[1]).shape;
            let out_spatial: usize = node.shape[0] * node.shape[2] * node.shape[3];
            let cols = out_spatial * w[1] * w[2] * w[3] * 4;
            cols + node.byte_size()
        }
        Op::FusedAttention { .. } => {
            // running stats + one kv-block of scores per batch element
            let q = &graph.node(node.inputs[0]).shape;
            let sq = q[q.len() - 2];
            sq * (crate::tensor::attention::KV_BLOCK + 2) * 4
        }
        _ => 0,
    }
}

/// Bytes a node newly allocates for its output (0 for views / aliasing).
fn alloc_bytes(graph: &Graph, id: NodeId, contig: &[bool]) -> usize {
    let node = graph.node(id);
    if is_view(&node.op) {
        return 0;
    }
    if matches!(node.op, Op::Reshape) && contig[node.inputs[0]] {
        return 0; // zero-copy reshape
    }
    node.byte_size()
}

/// Scale factor (≤ 1) applied to region-node allocations under a plan:
/// `ceil(extent/n) / extent` along the node's chunk dim. Region *outputs*
/// accumulate at full size (Eq. 2 keeps `mem(Y)` whole), so they scale 1.
fn chunk_scale(graph: &Graph, plan: &ChunkPlan, id: NodeId) -> f64 {
    if plan.outputs.iter().any(|&(o, _)| o == id) {
        return 1.0;
    }
    let dim = plan.node_dims[&id];
    let extent = graph.node(id).shape[dim];
    let step = extent.div_ceil(plan.n_chunks);
    step as f64 / extent as f64
}

/// Core simulator shared by [`estimate`], [`estimate_under_plan`] and
/// [`peak_upper_bound`].
///
/// `pessimistic` switches the model from *best estimate* (what chunk
/// selection iterates against) to *upper bound* (what serving admission
/// prices requests with — see [`cost_quote`]):
///
/// * kernel workspace is charged as if every input were non-contiguous,
///   plus one materialized copy of every input (any kernel may
///   `to_contiguous` its operands);
/// * reshapes always copy (the zero-copy alias is an optimization the
///   bound must not rely on);
/// * for each chunk region, the output accumulators (full size) and the
///   contiguated pass-input copies are pre-charged at the region head and
///   held until the region's last node — mirroring the chunked executor's
///   `Accumulator`s and loop-invariant pass materialization;
/// * values consumed inside a chunk region are not freed until the region
///   completes (the executor releases region scratch at iteration end and
///   external inputs after the loop).
fn simulate(graph: &Graph, plans: &[ChunkPlan], pessimistic: bool) -> MemoryProfile {
    let users = graph.users();
    let mut refcount: Vec<usize> = users.iter().map(|u| u.len()).collect();
    for &o in &graph.outputs {
        refcount[o] += 1;
    }
    let owner = region_owner(plans, graph.len());

    // Pessimistic region bookkeeping: pre-charge per plan, release point.
    let mut precharge: Vec<usize> = vec![0; plans.len()];
    let mut region_head: Vec<NodeId> = vec![usize::MAX; plans.len()];
    let mut region_last: Vec<NodeId> = vec![usize::MAX; plans.len()];
    if pessimistic {
        for (pi, p) in plans.iter().enumerate() {
            let outs: usize = p.outputs.iter().map(|&(o, _)| graph.node(o).byte_size()).sum();
            let pass: usize = p
                .pass_inputs
                .iter()
                .map(|&i| graph.node(i).byte_size())
                .sum();
            precharge[pi] = outs + pass;
            region_head[pi] = *p.region.first().unwrap_or(&usize::MAX);
            region_last[pi] = *p.region.last().unwrap_or(&usize::MAX);
        }
    }
    let mut deferred: Vec<Vec<NodeId>> = vec![Vec::new(); plans.len()];
    let all_noncontig: Vec<bool> = if pessimistic { vec![false; graph.len()] } else { Vec::new() };

    // Aliasing: each value references a storage root; roots carry bytes.
    let mut root: Vec<NodeId> = (0..graph.len()).collect();
    let mut root_bytes: Vec<usize> = vec![0; graph.len()];
    let mut root_refs: Vec<usize> = vec![0; graph.len()];
    let mut contig: Vec<bool> = vec![true; graph.len()];

    let mut live: usize = 0;
    let mut peak: usize = 0;
    let mut peak_node: NodeId = 0;
    let mut per_node: Vec<usize> = Vec::with_capacity(graph.len());

    let free_value = |id: NodeId,
                          root: &[NodeId],
                          root_bytes: &mut [usize],
                          root_refs: &mut [usize],
                          live: &mut usize| {
        let r = root[id];
        root_refs[r] -= 1;
        if root_refs[r] == 0 {
            *live -= root_bytes[r];
            root_bytes[r] = 0;
        }
    };

    for node in &graph.nodes {
        let id = node.id;
        contig[id] = output_contiguous(graph, id, &contig);

        // Accumulators + pass-input copies appear when the region starts.
        if pessimistic {
            for (pi, &h) in region_head.iter().enumerate() {
                if h == id {
                    live += precharge[pi];
                    if live > peak {
                        peak = live;
                        peak_node = id;
                    }
                }
            }
        }

        // Parameters occupy parameter memory, not activation memory.
        // Persistent inputs (KV caches) are resident state charged by the
        // serving tier, not per-run activation (DESIGN.md §13). This is
        // what makes a chunked-prefill slice graph cheap to admit: the
        // cached prefix it re-binds is excluded here and priced once as
        // residency, so a slice's quote scales with its `n` rows, not the
        // full prompt (DESIGN.md §17).
        let is_param = matches!(node.op, Op::Param) || graph.is_persistent(id);

        // Region scaling: intermediates of a chunked region cost 1/n.
        let scale = owner[id]
            .map(|pi| chunk_scale(graph, &plans[pi], id))
            .unwrap_or(1.0);

        // Frees triggered while executing a chunk region hold until the
        // region completes (pessimistic mode only).
        let defer_to = if pessimistic { owner[id] } else { None };

        // `root_refs[r]` counts live *values* aliasing root r: each node id
        // holds exactly one reference from birth until its own refcount
        // (consumer countdown) hits zero.
        if node.op.is_leaf() {
            root_bytes[id] = if is_param { 0 } else { node.byte_size() };
            root_refs[id] = 1;
            live += root_bytes[id];
            if refcount[id] == 0 {
                free_value(id, &root, &mut root_bytes, &mut root_refs, &mut live);
            }
        } else {
            // Views alias their input's root (pessimistic mode does not
            // trust the zero-copy reshape).
            let aliases = is_view(&node.op)
                || (matches!(node.op, Op::Reshape) && contig[node.inputs[0]] && !pessimistic);
            if aliases {
                let r = root[node.inputs[0]];
                root[id] = r;
                root_refs[r] += 1;
                if refcount[id] == 0 {
                    match defer_to {
                        Some(pi) => deferred[pi].push(id),
                        None => free_value(id, &root, &mut root_bytes, &mut root_refs, &mut live),
                    }
                }
            } else {
                let (out, ws) = if pessimistic {
                    // Any kernel may materialize a non-contiguous operand
                    // with `to_contiguous`; contiguous values (leaves are
                    // always bound contiguous) are never copied that way.
                    let inputs_copied: usize = node
                        .inputs
                        .iter()
                        .filter(|&&i| !contig[i])
                        .map(|&i| graph.node(i).byte_size())
                        .sum();
                    let out = (alloc_bytes(graph, id, &all_noncontig) as f64 * scale) as usize;
                    // workspace deliberately left unscaled under plans
                    (out, node_workspace(graph, id, &all_noncontig) + inputs_copied)
                } else {
                    let out = (alloc_bytes(graph, id, &contig) as f64 * scale) as usize;
                    let ws = (node_workspace(graph, id, &contig) as f64 * scale) as usize;
                    (out, ws)
                };
                // workspace + output live simultaneously at the peak moment
                if live + ws + out > peak {
                    peak = live + ws + out;
                    peak_node = id;
                }
                root_bytes[id] = out;
                root_refs[id] = 1;
                live += out;
                if refcount[id] == 0 {
                    // dead code: free immediately (or at region end)
                    match defer_to {
                        Some(pi) => deferred[pi].push(id),
                        None => free_value(id, &root, &mut root_bytes, &mut root_refs, &mut live),
                    }
                }
            }
            // Inputs whose last consumer this was are released.
            for &i in &node.inputs {
                refcount[i] -= 1;
                if refcount[i] == 0 {
                    match defer_to {
                        Some(pi) => deferred[pi].push(i),
                        None => free_value(i, &root, &mut root_bytes, &mut root_refs, &mut live),
                    }
                }
            }
        }
        // Region end: drop deferred values and the region pre-charge.
        if pessimistic {
            for (pi, &l) in region_last.iter().enumerate() {
                if l == id {
                    for v in std::mem::take(&mut deferred[pi]) {
                        free_value(v, &root, &mut root_bytes, &mut root_refs, &mut live);
                    }
                    live -= precharge[pi];
                }
            }
        }
        if live > peak {
            peak = live;
            peak_node = id;
        }
        per_node.push(live);
    }

    MemoryProfile {
        per_node,
        peak_bytes: peak,
        peak_node,
        persistent_bytes: graph.persistent_bytes(),
    }
}


/// Activation-memory profile of the unchunked graph.
pub fn estimate(graph: &Graph) -> MemoryProfile {
    simulate(graph, &[], false)
}

/// Profile under a set of chunk plans (Eq. 2: region intermediates scale by
/// `1/n`; region inputs/outputs stay whole).
pub fn estimate_under_plan(graph: &Graph, plans: &[ChunkPlan]) -> MemoryProfile {
    simulate(graph, plans, false)
}

/// Conservative upper bound on the measured peak activation bytes of one
/// execution of `graph` under `plans` (empty = unchunked). Unlike
/// [`estimate`], which aims to *track* the interpreter, this bound may only
/// err high — it is what serving admission control packs against, so a
/// wave of co-resident requests whose bounds sum below the budget cannot
/// exceed it.
pub fn peak_upper_bound(graph: &Graph, plans: &[ChunkPlan]) -> usize {
    let pess = simulate(graph, plans, true).peak_bytes;
    // Never report below the best estimate (the bound must dominate it).
    pess.max(simulate(graph, plans, false).peak_bytes)
}

/// Per-request cost quote: everything the serving tier needs to admit a
/// request under a memory budget (ISSUE: the admission controller packs
/// waves by `peak + (d − 1) · per_chunk` — the PR-1 governor formula).
#[derive(Clone, Copy, Debug)]
pub struct CostQuote {
    /// Upper bound on the measured peak of one serial execution
    /// ([`peak_upper_bound`]). Admission charges this per request.
    pub peak_bytes: usize,
    /// Price of one *extra* in-flight chunk iteration: the largest
    /// [`per_chunk_bytes`] across the plans (0 when unchunked).
    pub per_chunk_bytes: usize,
    /// The tracking estimate ([`estimate_under_plan`] peak) — what the
    /// executor's concurrency governor prices headroom against.
    pub estimate_bytes: usize,
    /// Bytes of persistent (cross-execution) inputs the graph binds —
    /// excluded from `peak_bytes` and charged by the serving tier as
    /// resident state. For paged decode graphs this is *block
    /// granularity* (`2·layers·nblk·h·block_tokens·dh·4` — blocks the
    /// request actually holds), not bucket capacity (DESIGN.md §14), so
    /// admission can cross-check its residency charge against the quote.
    pub persistent_bytes: usize,
    /// Bytes the memory planner's spill placements move across the slow
    /// tier per execution (out + back in; 0 without placements — the quote
    /// itself never plans, `PlanHandle` fills this from the `MemPlan`).
    pub spill_transfer_bytes: usize,
    /// Modeled FLOPs of recompute placements per execution (0 without).
    pub spill_recompute_flops: usize,
}

impl CostQuote {
    /// Admission price of running this request with `degree` chunk
    /// iterations in flight: `peak + (degree − 1) · per_chunk`.
    pub fn admission_bytes(&self, degree: usize) -> usize {
        self.peak_bytes + degree.saturating_sub(1) * self.per_chunk_bytes
    }

    /// Budget to hand the executor's concurrency governor so that
    /// *measured* peak stays under `budget`: the governor prices headroom
    /// from `estimate_bytes`, so the gap between the upper bound and the
    /// estimate must be reserved up front.
    pub fn governor_budget(&self, budget: usize) -> usize {
        budget.saturating_sub(self.peak_bytes.saturating_sub(self.estimate_bytes))
    }
}

/// How much tighter the static memory planner's exact admission price is
/// than the pessimistic quote — the headroom the serve engine recovers by
/// pricing with the planner (ISSUE 3). Arena-mode admission charges
/// `planned_admission` directly (the certified bound for what the arena
/// executor runs); the quote stays the *reported* cross-check ceiling —
/// `planned_peak` (arena values only) always sits below it, and this
/// report surfaces the per-plan difference.
#[derive(Clone, Copy, Debug)]
pub struct PlannerGap {
    /// Exact planned arena peak (intermediates only).
    pub planned_peak: usize,
    /// The planner's sound serial admission price (inputs + arena +
    /// transient workspace).
    pub planned_admission: usize,
    /// The pessimistic quote's upper bound.
    pub quote_peak: usize,
    /// Bytes the planner recovers per admitted request
    /// (`quote_peak - min(planned_admission, quote_peak)`).
    pub gap_bytes: usize,
}

impl PlannerGap {
    /// Recovered fraction of the quote (0.0 when the quote is tighter).
    pub fn gap_frac(&self) -> f64 {
        self.gap_bytes as f64 / self.quote_peak.max(1) as f64
    }
}

/// Compare the static memory planner against the pessimistic quote for a
/// (graph, plans) pair.
pub fn planner_gap(graph: &Graph, plans: &[ChunkPlan]) -> PlannerGap {
    let mem = crate::passes::memplan::plan_memory(graph, plans);
    let quote_peak = peak_upper_bound(graph, plans);
    let planned_admission = mem.admission_bytes(1);
    PlannerGap {
        planned_peak: mem.planned_peak_bytes,
        planned_admission,
        quote_peak,
        gap_bytes: quote_peak.saturating_sub(planned_admission.min(quote_peak)),
    }
}

/// Quote a (graph, plans) pair for admission control.
pub fn cost_quote(graph: &Graph, plans: &[ChunkPlan]) -> CostQuote {
    let estimate_bytes = simulate(graph, plans, false).peak_bytes;
    let peak_bytes = simulate(graph, plans, true).peak_bytes.max(estimate_bytes);
    let per_chunk = plans
        .iter()
        .map(|p| per_chunk_bytes(graph, p))
        .max()
        .unwrap_or(0);
    CostQuote {
        peak_bytes,
        per_chunk_bytes: per_chunk,
        estimate_bytes,
        persistent_bytes: graph.persistent_bytes(),
        spill_transfer_bytes: 0,
        spill_recompute_flops: 0,
    }
}

/// Upper bound on the activation bytes one chunk *iteration* of `plan`
/// holds live at once: the sum of every region node's output scaled to
/// the chunk step along its assigned dim, plus the largest kernel
/// workspace. Two deliberate over-approximations keep this a bound
/// rather than an estimate — the executor's concurrency governor prices
/// one extra in-flight iteration at this many bytes, and erring high
/// keeps parallel runs under budget:
///
/// * outputs are *summed*, not liveness-tracked;
/// * workspace is charged as if every kernel input were non-contiguous
///   (chunk-input slices often are) and is left unscaled.
pub fn per_chunk_bytes(graph: &Graph, plan: &ChunkPlan) -> usize {
    let contig = vec![false; graph.len()];
    let mut sum = 0usize;
    let mut max_ws = 0usize;
    for &r in &plan.region {
        let node = graph.node(r);
        let dim = plan.node_dims[&r];
        let extent = node.shape[dim].max(1);
        let step = extent.div_ceil(plan.n_chunks.max(1));
        sum += node.byte_size() / extent * step;
        max_ws = max_ws.max(node_workspace(graph, r, &contig));
    }
    sum + max_ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute, random_inputs, random_params};
    use crate::ir::GraphBuilder;
    use crate::tensor::ops::{BinaryOp, UnaryOp};
    use crate::tensor::MemoryTracker;

    /// Build a toy transformer-ish block with a fat intermediate.
    fn fat_graph(s: usize, d: usize) -> crate::ir::Graph {
        let mut b = GraphBuilder::new("fat");
        let x = b.input("x", &[s, d]);
        let w = b.param("w", &[d, d]);
        let q = b.matmul(x, w);
        let kt = b.transpose(q, &[1, 0]);
        let scores = b.matmul(q, kt); // [s, s] — the fat one
        let probs = b.softmax(scores, 1);
        let out = b.matmul(probs, q);
        b.finish(vec![out])
    }

    #[test]
    fn peak_is_the_quadratic_intermediate() {
        let g = fat_graph(256, 16);
        let p = estimate(&g);
        // scores/softmax [256,256] dominate [256,16] tensors
        let peak_name = &g.node(p.peak_node).name;
        assert!(
            peak_name == "matmul" || peak_name == "softmax",
            "unexpected peak node {peak_name}"
        );
        assert!(p.peak_bytes >= 256 * 256 * 4);
    }

    #[test]
    fn estimate_matches_measured_peak() {
        // The estimator must track the real interpreter closely.
        for (name, g) in [
            ("fat", fat_graph(128, 32)),
            ("mlp", {
                let mut b = GraphBuilder::new("mlp");
                let x = b.input("x", &[64, 64]);
                let w1 = b.param("w1", &[64, 256]);
                let b1 = b.param("b1", &[256]);
                let w2 = b.param("w2", &[256, 64]);
                let b2 = b.param("b2", &[64]);
                let h = b.linear(x, w1, b1);
                let a = b.unary(UnaryOp::Gelu, h);
                let y = b.linear(a, w2, b2);
                b.finish(vec![y])
            }),
        ] {
            let est = estimate(&g).peak_bytes;
            let tracker = MemoryTracker::new();
            let ins = random_inputs(&g, 3, Some(tracker.clone()));
            let ps = random_params(&g, 4);
            let (_, stats) = execute(&g, &ins, &ps, &tracker);
            let measured = stats.peak_bytes;
            let ratio = est as f64 / measured as f64;
            assert!(
                (0.65..=1.5).contains(&ratio),
                "{name}: estimate {est} vs measured {measured} (ratio {ratio:.2})"
            );
        }
    }

    #[test]
    fn profile_length_matches_nodes() {
        let g = fat_graph(32, 8);
        let p = estimate(&g);
        assert_eq!(p.per_node.len(), g.len());
        // peak may exceed the live-set series due to transient workspace
        assert!(p.peak_bytes >= *p.per_node.iter().max().unwrap());
    }

    #[test]
    fn fraction_below_distribution() {
        // In a graph with one fat intermediate, most nodes sit well below
        // the peak — the paper's Figure-4 skew.
        let g = fat_graph(512, 16);
        let p = estimate(&g);
        assert!(p.fraction_below(0.5) > 0.4, "{}", p.fraction_below(0.5));
    }

    #[test]
    fn params_are_not_activation() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let w = b.param("w", &[1024, 1024]); // huge param
        let w0 = b.slice(w, 0, 0, 1);
        let w1 = b.reshape(w0, &[1024]);
        let s = b.reduce(crate::tensor::reduce::ReduceOp::Sum, w1, 0, false);
        let sb = b.broadcast(s, &[4]);
        let y = b.binary(BinaryOp::Add, x, sb);
        let g = b.finish(vec![y]);
        let p = estimate(&g);
        // peak must be tiny — the 4 MiB parameter doesn't count.
        assert!(p.peak_bytes < 100_000, "{}", p.peak_bytes);
    }

    #[test]
    fn views_do_not_allocate() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[64, 64]);
        let t1 = b.transpose(x, &[1, 0]);
        let s1 = b.slice(t1, 0, 0, 32);
        let g = b.finish(vec![s1]);
        let p = estimate(&g);
        // only the input allocates
        assert_eq!(p.peak_bytes, 64 * 64 * 4);
    }

    #[test]
    fn quote_dominates_estimate_and_prices_degree() {
        let g = fat_graph(128, 16);
        let q = cost_quote(&g, &[]);
        let est = estimate(&g).peak_bytes;
        assert_eq!(q.estimate_bytes, est);
        assert!(q.peak_bytes >= est, "bound {} below estimate {est}", q.peak_bytes);
        assert_eq!(q.per_chunk_bytes, 0, "unchunked quote has no per-chunk price");
        assert_eq!(q.admission_bytes(1), q.peak_bytes);
        assert_eq!(q.admission_bytes(4), q.peak_bytes);
        // governor budget reserves the bound-vs-estimate gap
        let b = q.peak_bytes * 2;
        assert_eq!(q.governor_budget(b), b - (q.peak_bytes - q.estimate_bytes));
    }

    #[test]
    fn upper_bound_covers_measured_peak() {
        for (name, g) in [("fat", fat_graph(96, 16)), ("fat2", fat_graph(64, 32))] {
            let bound = peak_upper_bound(&g, &[]);
            let tracker = MemoryTracker::new();
            let ins = random_inputs(&g, 9, Some(tracker.clone()));
            let ps = random_params(&g, 10);
            let (_, stats) = execute(&g, &ins, &ps, &tracker);
            assert!(
                bound >= stats.peak_bytes,
                "{name}: bound {bound} below measured {}",
                stats.peak_bytes
            );
        }
    }

    #[test]
    fn under_plan_shrinks_peak() {
        use std::collections::HashMap;
        let g = fat_graph(256, 16);
        // Hand-build a plan chunking the scores+softmax region (nodes 4,5:
        // matmul scores, softmax) along dim 0.
        // Find them by name/shape.
        let scores = g
            .nodes
            .iter()
            .find(|n| n.op == crate::ir::Op::MatMul && n.shape == vec![256, 256])
            .unwrap()
            .id;
        let softmax = scores + 1;
        let out_mm = g.outputs[0];
        let mut node_dims = HashMap::new();
        node_dims.insert(scores, 0);
        node_dims.insert(softmax, 0);
        node_dims.insert(out_mm, 0);
        let q = g.node(scores).inputs[0];
        let kt = g.node(scores).inputs[1];
        let plan = ChunkPlan {
            region: vec![scores, softmax, out_mm],
            chunk_inputs: vec![(q, 0)],
            pass_inputs: vec![kt, q]
                .into_iter()
                .filter(|&n| n != q)
                .collect(),
            outputs: vec![(out_mm, 0)],
            n_chunks: 8,
            node_dims,
        };
        assert!(plan.validate(&g).is_ok(), "{:?}", plan.validate(&g));
        let base = estimate(&g).peak_bytes;
        let chunked = estimate_under_plan(&g, &[plan]).peak_bytes;
        assert!(
            (chunked as f64) < 0.45 * base as f64,
            "chunked {chunked} vs base {base}"
        );
    }

    #[test]
    fn prefill_slice_priced_at_slice_scale_not_prompt_scale() {
        use crate::models::{gpt, gpt_prefill_chunk, GptConfig};
        // What makes chunked-prefill admission work: the slice graph's
        // cached prefix is a persistent input — resident state the engine
        // prices separately — so the slice's activation quote tracks its
        // own `n` rows, not the whole prompt.
        let cfg = GptConfig { seq: 256, ..Default::default() };
        let full = estimate(&gpt(&cfg)).peak_bytes;
        let slice = estimate(&gpt_prefill_chunk(&cfg, 224, 32, 0));
        assert!(slice.persistent_bytes > 0, "cached prefix must be persistent");
        assert!(
            slice.peak_bytes < full,
            "32-row slice ({}) must be cheaper than the 256-row prefill ({full})",
            slice.peak_bytes
        );
        // and the prefix bytes are in the persistent channel, not the peak
        let first_slice = estimate(&gpt_prefill_chunk(&cfg, 0, 32, 0));
        assert_eq!(first_slice.persistent_bytes, 0, "past-0 slice binds no cache");
    }
}
