//! Chunk search pass (paper §3.3, Algorithm 1).
//!
//! Enumerates node pairs `(start, end)` around the peak-memory node inside
//! a local window (`O(k²·N)` instead of `O(N³)`), and for each candidate
//! region and each output dimension runs a bottom-up BFS over chunk flows
//! to assign every region node a chunk dimension (Rules 1–4, Eq. 5–7).
//!
//! Complexity optimizations from the paper:
//! * **local window** — only regions within `window` nodes of the peak;
//! * **two-stage filter** — a cheap single-path trace rejects hopeless
//!   (region, dim) pairs before the full BFS;
//! * **graph optimization** — nodes not reached by any flow are hoisted
//!   out of the region when legal (they don't depend on chunked values),
//!   instead of rejecting the whole candidate.

use super::estimate::MemoryProfile;
use super::flow::{propagate_to_input, FlowResult};
use crate::ir::{Graph, NodeId};
use crate::plan::ChunkPlan;
use crate::util::pool;
use std::collections::{HashMap, HashSet, VecDeque};

/// Tunables for the search pass.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Local window size `k`: regions start/end within this many nodes of
    /// the peak node.
    pub window: usize,
    /// Two-stage filtering (stage 1 = cheap boundary flow check).
    pub two_stage_filter: bool,
    /// Graph optimization: hoist flow-irrelevant nodes out of the region.
    pub graph_opt: bool,
    /// Hard cap on region length in nodes.
    pub max_region: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            window: 48,
            two_stage_filter: true,
            graph_opt: true,
            max_region: 96,
        }
    }
}

/// A legal chunk found by the search (chunk count not yet chosen —
/// selection completes it).
#[derive(Clone, Debug)]
pub struct ChunkCandidate {
    pub plan: ChunkPlan,
}

/// Search statistics (exposed for the complexity experiments).
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    pub regions_considered: usize,
    pub stage1_rejected: usize,
    pub stage2_runs: usize,
    pub candidates: usize,
}

/// Find all legal chunk candidates whose region contains the current peak
/// node and does not overlap `existing` plans.
pub fn search_chunks(
    graph: &Graph,
    profile: &MemoryProfile,
    existing: &[ChunkPlan],
    config: &SearchConfig,
) -> Vec<ChunkCandidate> {
    search_chunks_with_stats(graph, profile, existing, config).0
}

/// As [`search_chunks`], also returning statistics.
pub fn search_chunks_with_stats(
    graph: &Graph,
    profile: &MemoryProfile,
    existing: &[ChunkPlan],
    config: &SearchConfig,
) -> (Vec<ChunkCandidate>, SearchStats) {
    let mut stats = SearchStats::default();
    let mut out: Vec<ChunkCandidate> = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();

    let peak = profile.peak_node;
    let n = graph.len();
    let taken: HashSet<NodeId> = existing
        .iter()
        .flat_map(|p| p.region.iter().copied())
        .collect();

    let users = graph.users();
    let constant = const_derived(graph);

    let lo = peak.saturating_sub(config.window);
    let hi = (peak + config.window).min(n.saturating_sub(1));

    // Candidate evaluation is independent per region start, so the starts
    // fan out over the worker pool; results are merged back in start
    // order before the global dedup, which keeps the candidate list
    // identical to the serial sweep (selection stays deterministic).
    let starts: Vec<NodeId> = (lo..=peak)
        .filter(|&s| !graph.node(s).op.is_leaf())
        .collect();
    let users = &users;
    let constant = &constant;
    let taken = &taken;
    let per_start = pool::parallel_map(starts.len(), |si| {
        let start = starts[si];
        let mut local = SearchStats::default();
        let mut found: Vec<(String, ChunkPlan)> = Vec::new();
        'ends: for end in peak..=hi {
            if end < start || end - start + 1 > config.max_region {
                continue;
            }
            if graph.node(end).op.is_leaf() {
                continue;
            }
            // Region = non-leaf, non-constant nodes in [start, end],
            // disjoint from taken.
            let region: Vec<NodeId> = (start..=end)
                .filter(|&i| !graph.node(i).op.is_leaf() && !constant[i])
                .collect();
            if region.is_empty() || !region.contains(&peak) {
                continue;
            }
            for &r in &region {
                if taken.contains(&r) {
                    continue 'ends;
                }
            }
            local.regions_considered += 1;

            let region_set: HashSet<NodeId> = region.iter().copied().collect();
            // Outputs: region nodes consumed outside, or graph outputs.
            let outputs: Vec<NodeId> = region
                .iter()
                .copied()
                .filter(|&r| {
                    graph.outputs.contains(&r)
                        || users[r].iter().any(|&u| !region_set.contains(&u))
                })
                .collect();
            if outputs.is_empty() {
                continue;
            }

            // Seed the flow from each output in turn (Algorithm 1 iterates
            // the dims of the output nodes): the first output may be a
            // side value the flow cannot start from.
            for &out0 in outputs.iter().take(3) {
                let rank = graph.node(out0).shape.len();
                for dim in 0..rank {
                    if graph.node(out0).shape[dim] <= 1 {
                        continue;
                    }
                    if config.two_stage_filter && !stage1_trace(graph, &region_set, out0, dim) {
                        local.stage1_rejected += 1;
                        continue;
                    }
                    local.stage2_runs += 1;
                    if let Some(plan) =
                        trace_region(graph, users, &region, &outputs, out0, dim, config, Some(peak))
                    {
                        found.push((plan_key(&plan), plan));
                    }
                }
            }
        }
        (found, local)
    });
    for (found, local) in per_start {
        stats.regions_considered += local.regions_considered;
        stats.stage1_rejected += local.stage1_rejected;
        stats.stage2_runs += local.stage2_runs;
        for (key, plan) in found {
            if seen.insert(key) {
                debug_assert!(plan.validate(graph).is_ok(), "{:?}", plan.validate(graph));
                out.push(ChunkCandidate { plan });
            }
        }
    }
    stats.candidates = out.len();
    (out, stats)
}

/// Build a plan for an explicit node range and output chunk dim, without
/// peak anchoring — used by the expert-chunk baseline and by tests that
/// need a specific region.
pub fn plan_for_range(
    graph: &Graph,
    start: NodeId,
    end: NodeId,
    dim: usize,
    config: &SearchConfig,
) -> Option<ChunkPlan> {
    if end >= graph.len() || start > end {
        return None;
    }
    let users = graph.users();
    let constant = const_derived(graph);
    let region: Vec<NodeId> = (start..=end)
        .filter(|&i| !graph.node(i).op.is_leaf() && !constant[i])
        .collect();
    if region.is_empty() {
        return None;
    }
    let region_set: HashSet<NodeId> = region.iter().copied().collect();
    let outputs: Vec<NodeId> = region
        .iter()
        .copied()
        .filter(|&r| {
            graph.outputs.contains(&r) || users[r].iter().any(|&u| !region_set.contains(&u))
        })
        .collect();
    for &out0 in outputs.iter().take(3) {
        if dim >= graph.node(out0).shape.len() || graph.node(out0).shape[dim] <= 1 {
            continue;
        }
        if let Some(plan) =
            trace_region(graph, &users, &region, &outputs, out0, dim, config, None)
        {
            return Some(plan);
        }
    }
    None
}

/// Stage-1 filter: follow one greedy flow path from `(out0, dim)` upwards;
/// succeeds iff it escapes the region without hitting a broken edge.
fn stage1_trace(graph: &Graph, region: &HashSet<NodeId>, out0: NodeId, dim: usize) -> bool {
    let mut node = out0;
    let mut d = dim;
    for _ in 0..graph.len() {
        if !region.contains(&node) {
            return true; // escaped through an input
        }
        let inputs = &graph.node(node).inputs;
        if inputs.is_empty() {
            return false;
        }
        let mut advanced = false;
        for pos in 0..inputs.len() {
            match propagate_to_input(graph, node, d, pos) {
                FlowResult::Dim(di) => {
                    node = inputs[pos];
                    d = di;
                    advanced = true;
                    break;
                }
                FlowResult::NotCarried => continue,
                FlowResult::Broken => return false,
            }
        }
        if !advanced {
            return false;
        }
    }
    false
}

/// Stage-2: full bottom-up BFS assigning chunk dims to the whole region.
/// Returns a complete plan (n_chunks = 1) or None if illegal.
#[allow(clippy::too_many_arguments)]
fn trace_region(
    graph: &Graph,
    users: &[Vec<NodeId>],
    region: &[NodeId],
    outputs: &[NodeId],
    out0: NodeId,
    dim: usize,
    config: &SearchConfig,
    peak: Option<NodeId>,
) -> Option<ChunkPlan> {
    let region_set: HashSet<NodeId> = region.iter().copied().collect();
    let mut node_dims: HashMap<NodeId, usize> = HashMap::new();
    let mut chunk_inputs: HashMap<NodeId, usize> = HashMap::new();
    let mut pass_inputs: HashSet<NodeId> = HashSet::new();
    let mut queue: VecDeque<(NodeId, usize)> = VecDeque::new();

    node_dims.insert(out0, dim);
    queue.push_back((out0, dim));

    while let Some((id, d)) = queue.pop_front() {
        let node = graph.node(id);
        for pos in 0..node.inputs.len() {
            let input = node.inputs[pos];
            match propagate_to_input(graph, id, d, pos) {
                FlowResult::Broken => return None, // Rule 3 violated
                FlowResult::NotCarried => {
                    if !region_set.contains(&input) {
                        pass_inputs.insert(input);
                    }
                    // in-region NotCarried nodes handled after BFS
                }
                FlowResult::Dim(di) => {
                    if region_set.contains(&input) {
                        match node_dims.get(&input) {
                            Some(&prev) if prev != di => return None, // Rule 4
                            Some(_) => {}
                            None => {
                                node_dims.insert(input, di);
                                queue.push_back((input, di));
                            }
                        }
                    } else {
                        // flow escapes: chunkable input
                        if di >= graph.node(input).shape.len() {
                            return None; // degenerate (scalar/init operand)
                        }
                        match chunk_inputs.get(&input) {
                            Some(&prev) if prev != di => return None,
                            _ => {
                                chunk_inputs.insert(input, di);
                            }
                        }
                    }
                }
            }
        }
    }

    // Rule 3: at least one chunkable input must carry the flow.
    if chunk_inputs.is_empty() {
        return None;
    }

    // Rule 4, edge consistency: every edge between two *assigned* region
    // nodes must itself carry the flow with matching dims. The BFS only
    // walks carried edges; a second edge between the same pair may demand
    // the whole value (e.g. `x @ transpose(x)` consumes x both chunked
    // and whole — chunking would compute only the diagonal blocks).
    for (&r, &rd) in &node_dims {
        let node = graph.node(r);
        for pos in 0..node.inputs.len() {
            let i = node.inputs[pos];
            if let Some(&idim) = node_dims.get(&i) {
                match propagate_to_input(graph, r, rd, pos) {
                    FlowResult::Dim(di) if di == idim => {}
                    _ => return None,
                }
            } else if chunk_inputs.contains_key(&i) {
                // edges to chunk inputs must carry the flow consistently too
                match propagate_to_input(graph, r, rd, pos) {
                    FlowResult::Dim(di) if di == chunk_inputs[&i] => {}
                    _ => return None,
                }
            }
        }
    }

    // Handle region nodes not reached by any flow.
    let unassigned: Vec<NodeId> = region
        .iter()
        .copied()
        .filter(|r| !node_dims.contains_key(r))
        .collect();
    let mut final_region: Vec<NodeId> = region
        .iter()
        .copied()
        .filter(|r| node_dims.contains_key(r))
        .collect();
    if !unassigned.is_empty() {
        if !config.graph_opt {
            return None;
        }
        // Graph optimization: hoist nodes whose in-region dependencies are
        // all unassigned (flow-irrelevant). A node depending on an assigned
        // (chunked) node needs the full value — illegal.
        let assigned: HashSet<NodeId> = node_dims.keys().copied().collect();
        for &u in &unassigned {
            if graph
                .node(u)
                .inputs
                .iter()
                .any(|i| assigned.contains(i))
            {
                return None;
            }
        }
        // hoisted producers consumed by assigned nodes become pass inputs
        let unassigned_set: HashSet<NodeId> = unassigned.iter().copied().collect();
        for &u in &unassigned {
            if users[u].iter().any(|c| assigned.contains(c)) {
                pass_inputs.insert(u);
            }
        }
        // also anything external the hoisted nodes exposed is irrelevant now
        pass_inputs.retain(|p| {
            !unassigned_set.contains(p) || users[*p].iter().any(|c| assigned.contains(c))
        });
    }

    // Peak must remain inside the (possibly narrowed) region.
    if let Some(pk) = peak {
        if !final_region.contains(&pk) {
            return None;
        }
    }

    // Recompute outputs for the final region: chunked nodes consumed
    // outside it (hoisted consumers count as outside).
    let final_set: HashSet<NodeId> = final_region.iter().copied().collect();
    let mut plan_outputs: Vec<(NodeId, usize)> = Vec::new();
    for &r in &final_region {
        let is_out = graph.outputs.contains(&r)
            || users[r].iter().any(|u| !final_set.contains(u));
        if is_out {
            plan_outputs.push((r, node_dims[&r]));
        }
    }
    if plan_outputs.is_empty() {
        return None;
    }
    // All declared outputs of the original region must have been assigned —
    // otherwise the chunked region cannot reproduce them (Rule 2).
    for &o in outputs {
        if final_set.contains(&o) && !node_dims.contains_key(&o) {
            return None;
        }
    }

    // Rule 2 prerequisite: a single trip count — all outputs share the
    // chunk extent along their dims.
    let extent = graph.node(plan_outputs[0].0).shape[plan_outputs[0].1];
    if extent <= 1 {
        return None;
    }
    for &(o, od) in &plan_outputs {
        if graph.node(o).shape[od] != extent {
            return None;
        }
    }
    for (&i, &d) in &chunk_inputs {
        if graph.node(i).shape[d] != extent {
            return None; // flow preserved extents should guarantee this
        }
    }

    // Pass inputs must not also be chunk inputs (Rule 4 on inputs).
    for p in &pass_inputs {
        if chunk_inputs.contains_key(p) {
            return None;
        }
    }

    final_region.sort_unstable();
    let mut ci: Vec<(NodeId, usize)> = chunk_inputs.into_iter().collect();
    ci.sort_unstable();
    let mut pi: Vec<NodeId> = pass_inputs.into_iter().collect();
    pi.sort_unstable();
    plan_outputs.sort_unstable();

    Some(ChunkPlan {
        region: final_region,
        chunk_inputs: ci,
        pass_inputs: pi,
        outputs: plan_outputs,
        n_chunks: 1,
        node_dims,
    })
}

/// Nodes whose values depend only on constants/iota (no runtime inputs or
/// params): these are freely recomputable/hoistable and behave like leaves
/// for chunking purposes. JAX CSE shares e.g. `broadcast(const)` across
/// layers, which would otherwise turn them into spurious region outputs.
pub fn const_derived(graph: &Graph) -> Vec<bool> {
    let mut mask = vec![false; graph.len()];
    for node in &graph.nodes {
        mask[node.id] = match &node.op {
            crate::ir::Op::Const(_) | crate::ir::Op::Iota { .. } => true,
            crate::ir::Op::Input | crate::ir::Op::Param => false,
            _ => !node.inputs.is_empty() && node.inputs.iter().all(|&i| mask[i]),
        };
    }
    mask
}

/// Stable dedup key for a plan (region + dims + inputs).
fn plan_key(plan: &ChunkPlan) -> String {
    let mut dims: Vec<(NodeId, usize)> = plan.node_dims.iter().map(|(&k, &v)| (k, v)).collect();
    dims.sort_unstable();
    format!(
        "r{:?}ci{:?}d{:?}",
        plan.region, plan.chunk_inputs, dims
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::passes::estimate::estimate;
    use crate::tensor::ops::{BinaryOp, UnaryOp};

    /// attention-score-like graph: q,k from x, scores = q@k^T, softmax, @v.
    fn attn_graph(s: usize, d: usize) -> crate::ir::Graph {
        let mut b = GraphBuilder::new("attn");
        let x = b.input("x", &[s, d]);
        let wq = b.param("wq", &[d, d]);
        let wk = b.param("wk", &[d, d]);
        let wv = b.param("wv", &[d, d]);
        let q = b.matmul(x, wq);
        let k = b.matmul(x, wk);
        let v = b.matmul(x, wv);
        let kt = b.transpose(k, &[1, 0]);
        let scores = b.matmul(q, kt);
        let scaled = b.binary_scalar(BinaryOp::Mul, scores, 0.125);
        let probs = b.softmax(scaled, 1);
        let out = b.matmul(probs, v);
        b.finish(vec![out])
    }

    #[test]
    fn finds_candidates_in_attention() {
        let g = attn_graph(128, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        assert!(!cands.is_empty(), "no chunk candidates found");
        // At least one candidate must chunk along the query dim (0) —
        // the classic memory-efficient-attention chunk.
        assert!(
            cands.iter().any(|c| {
                c.plan.outputs.iter().all(|&(_, d)| d == 0)
                    && c.plan.chunk_inputs.iter().any(|&(_, d)| d == 0)
            }),
            "no query-dim chunk among {} candidates",
            cands.len()
        );
    }

    #[test]
    fn candidates_validate_against_graph() {
        let g = attn_graph(64, 8);
        let p = estimate(&g);
        for c in search_chunks(&g, &p, &[], &SearchConfig::default()) {
            assert!(c.plan.validate(&g).is_ok(), "{:?}", c.plan.validate(&g));
        }
    }

    #[test]
    fn no_candidate_chunks_softmax_axis() {
        let g = attn_graph(64, 8);
        let p = estimate(&g);
        let softmax_id = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::ir::Op::Softmax { .. }))
            .unwrap()
            .id;
        for c in search_chunks(&g, &p, &[], &SearchConfig::default()) {
            if let Some(&d) = c.plan.node_dims.get(&softmax_id) {
                assert_ne!(d, 1, "softmax chunked along its own axis");
            }
        }
    }

    #[test]
    fn respects_existing_plans() {
        let g = attn_graph(64, 8);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let first = cands[0].plan.clone();
        let more = search_chunks(&g, &p, &[first.clone()], &SearchConfig::default());
        for c in &more {
            assert!(
                !crate::plan::plans_overlap(&first, &c.plan),
                "overlapping candidate returned"
            );
        }
    }

    #[test]
    fn window_limits_search() {
        let g = attn_graph(64, 8);
        let p = estimate(&g);
        let narrow = SearchConfig {
            window: 2,
            ..Default::default()
        };
        let wide = SearchConfig {
            window: 64,
            ..Default::default()
        };
        let (c_narrow, s_narrow) = search_chunks_with_stats(&g, &p, &[], &narrow);
        let (c_wide, s_wide) = search_chunks_with_stats(&g, &p, &[], &wide);
        assert!(s_narrow.regions_considered < s_wide.regions_considered);
        assert!(c_narrow.len() <= c_wide.len());
    }

    #[test]
    fn stage1_filter_reduces_stage2_runs() {
        let g = attn_graph(64, 8);
        let p = estimate(&g);
        let with = SearchConfig {
            two_stage_filter: true,
            ..Default::default()
        };
        let without = SearchConfig {
            two_stage_filter: false,
            ..Default::default()
        };
        let (cw, sw) = search_chunks_with_stats(&g, &p, &[], &with);
        let (co, so) = search_chunks_with_stats(&g, &p, &[], &without);
        assert!(sw.stage2_runs <= so.stage2_runs);
        // the filter must not lose candidates
        assert_eq!(cw.len(), co.len());
    }

    #[test]
    fn graph_opt_hoists_irrelevant_nodes() {
        // region with a side computation independent of the chunk flow:
        // y = relu(x) + g(bias) where g(bias) has no chunk dim.
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[256, 64]);
        let bias = b.input("bias", &[64]);
        let bx = b.unary(UnaryOp::Exp, bias); // irrelevant flow node
        let r = b.unary(UnaryOp::Relu, x);
        let r2 = b.unary(UnaryOp::Gelu, r);
        let y = b.binary(BinaryOp::Add, r2, bx);
        let g = b.finish(vec![y]);
        let p = estimate(&g);
        let with_opt = search_chunks(&g, &p, &[], &SearchConfig::default());
        let without_opt = search_chunks(
            &g,
            &p,
            &[],
            &SearchConfig {
                graph_opt: false,
                ..Default::default()
            },
        );
        // graph_opt finds strictly more/equal candidates (it can save
        // regions that contain the exp(bias) node by hoisting it)
        assert!(with_opt.len() >= without_opt.len());
        // and at least one hoisted-region candidate excludes the exp node
        let exp_id = bx;
        assert!(with_opt.iter().any(|c| !c.plan.region.contains(&exp_id)
            && c.plan.pass_inputs.contains(&exp_id)));
    }

    #[test]
    fn chunk_extent_consistency() {
        let g = attn_graph(96, 8);
        let p = estimate(&g);
        for c in search_chunks(&g, &p, &[], &SearchConfig::default()) {
            let ext = c.plan.chunk_extent(&g);
            for &(i, d) in &c.plan.chunk_inputs {
                assert_eq!(g.node(i).shape[d], ext);
            }
        }
    }
}
