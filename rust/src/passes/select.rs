//! Chunk selection pass (paper §3.4).
//!
//! Scores every candidate with the macro + micro cost function
//!
//! ```text
//! L(s) = α·N_node + β·N_flop          (Eq. 8, macro)
//!      + γ·D(density) + λ·S(stride)   (Eq. 9, micro)
//! ```
//!
//! and completes it with a chunk count `n` — the smallest power of two
//! whose estimated peak (under all previously chosen plans, Eq. 2) fits
//! the budget. Terms:
//!
//! * `N_node`, `N_flop` — fraction of the graph's nodes/FLOPs inside the
//!   region: chunking more of the graph exposes more per-iteration
//!   overhead.
//! * density — FLOPs per node *per chunk*: high-density regions (big
//!   matmuls) retain parallelism when decomposed, so their cost term is
//!   inverted — `D = 1/(1+ln(1+density_norm))`. Using per-chunk density
//!   also folds the chunk count into the score: more chunks → thinner
//!   work → higher cost.
//! * stride — the number of non-contiguous memory runs the loop's
//!   slice/concat traffic generates across all chunk inputs and outputs:
//!   chunking an outer (large-stride) dimension is a handful of large
//!   memcpys, an inner dimension is thousands of scattered ones.
//!
//! Every term can be disabled for the Table-1 ablations.

use super::search::ChunkCandidate;
use crate::ir::{flops::node_flops, Graph};
use crate::passes::estimate::estimate_under_plan;
use crate::plan::ChunkPlan;
use crate::util::pool;

/// Weights + feature flags of the cost function.
#[derive(Clone, Debug)]
pub struct SelectConfig {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub lambda: f64,
    /// Ablation flags (Table 1).
    pub use_node_count: bool,
    pub use_flops: bool,
    pub use_density: bool,
    pub use_stride: bool,
    /// Cap on the number of chunks per plan.
    pub max_chunks: usize,
    /// A candidate must cut the current peak by at least this fraction.
    pub min_progress: f64,
}

impl Default for SelectConfig {
    fn default() -> Self {
        SelectConfig {
            alpha: 1.0,
            beta: 1.0,
            gamma: 2.0,
            lambda: 1.0,
            use_node_count: true,
            use_flops: true,
            use_density: true,
            use_stride: true,
            max_chunks: 256,
            min_progress: 0.02,
        }
    }
}

/// Modeled compute rate for recompute placements (DESIGN.md §18): the
/// effective GFLOP/s the latency-vs-peak objective prices re-execution at.
/// A model constant, not a measurement — it only has to rank recompute
/// against the spill transfer priced by `AUTOCHUNK_SPILL_GBPS`.
pub const RECOMPUTE_GFLOPS: f64 = 8.0;

/// Latency price, in microseconds, of one placement decision under the
/// selection objective: `bytes_moved` across a `gbps` GB/s slow tier plus
/// `flops` of recompute at [`RECOMPUTE_GFLOPS`]. The memory planner's
/// placement search uses this as its tiebreak (peak first, then cheapest
/// modeled latency), and the long-context bench reports the same model as
/// its tok/s penalty.
pub fn placement_cost_us(bytes_moved: usize, flops: usize, gbps: f64) -> f64 {
    let transfer = if gbps > 0.0 {
        bytes_moved as f64 / (gbps * 1e9) * 1e6
    } else {
        0.0
    };
    let recompute = flops as f64 / (RECOMPUTE_GFLOPS * 1e9) * 1e6;
    transfer + recompute
}

/// A selected plan with its cost.
#[derive(Clone, Debug)]
pub struct ScoredChunk {
    pub plan: ChunkPlan,
    pub cost: f64,
    /// Whether this plan (with prior plans) meets the budget by itself.
    pub meets_budget: bool,
    /// Estimated peak under prior plans + this one.
    pub peak_after: usize,
    /// The region's chunked footprint per Eq. 2 — `mem(X^c) + mem(Y) +
    /// mem(A)/n` — i.e. what the plan leaves behind locally: chunk inputs
    /// and outputs stay whole, interior activations divide by n. Primary
    /// ranking key when no candidate meets the budget: `peak_after` alone
    /// is myopic (gated by sibling regions), and a plan that starts just
    /// after or ends right at the hotspot strands it at full size.
    pub eq2_footprint: usize,
}

/// L(s) for a candidate at a given chunk count (Eq. 8–10).
pub fn score(graph: &Graph, plan: &ChunkPlan, config: &SelectConfig) -> f64 {
    let n = plan.n_chunks.max(1);
    let region_nodes = plan.region.len() as f64;
    let region_flops: f64 = plan
        .region
        .iter()
        .map(|&r| node_flops(graph, r) as f64)
        .sum();
    let total_nodes = graph.len() as f64;
    let total_flops = (graph.total_flops() as f64).max(1.0);

    let mut cost = 0.0;

    // ---- macro (Eq. 8): size of the chunked region
    if config.use_node_count {
        cost += config.alpha * (region_nodes / total_nodes);
    }
    if config.use_flops {
        cost += config.beta * (region_flops / total_flops);
    }

    // ---- micro (Eq. 9): density + stride
    if config.use_density {
        // per-chunk FLOPs per node, normalized by the graph's average
        let per_chunk_density = region_flops / (n as f64) / region_nodes.max(1.0);
        let graph_density = total_flops / total_nodes;
        let density_norm = per_chunk_density / graph_density.max(1.0);
        cost += config.gamma / (1.0 + (1.0 + density_norm).ln());
    }
    if config.use_stride {
        // total contiguous runs generated by slice (inputs) + concat
        // (outputs) over the whole loop
        let mut runs: f64 = 0.0;
        for &(i, d) in &plan.chunk_inputs {
            let shape = &graph.node(i).shape;
            let prefix: usize = shape[..d].iter().product::<usize>().max(1);
            runs += (prefix * n) as f64;
        }
        for &(o, d) in &plan.outputs {
            let shape = &graph.node(o).shape;
            let prefix: usize = shape[..d].iter().product::<usize>().max(1);
            runs += (prefix * n) as f64;
        }
        cost += config.lambda * (1.0 + runs).ln() / 10.0;
    }
    cost
}

/// Choose the chunk count for `cand` under `existing` plans: the smallest
/// power of two (≥2) whose estimated peak fits `budget`; if none fits, the
/// largest allowed count — progress still helps, later passes continue.
/// Returns None if even the largest count fails `min_progress`.
pub fn choose_n_chunks(
    graph: &Graph,
    cand: &ChunkCandidate,
    existing: &[ChunkPlan],
    budget: usize,
    config: &SelectConfig,
) -> Option<usize> {
    let extent = cand.plan.chunk_extent(graph);
    let current = estimate_under_plan(graph, existing);
    let max_n = config.max_chunks.min(extent).max(2);

    let mut n = 2usize;
    let mut progressing: Option<usize> = None;
    let mut footprint_ok: Option<usize> = None;
    let mut knee: Option<usize> = None;
    let mut prev_fp: Option<usize> = None;
    while n <= max_n {
        let mut plan = cand.plan.clone();
        plan.n_chunks = n;
        let in_region = plan.contains(current.peak_node);
        let fp = eq2_footprint(graph, &plan);
        let mut plans = existing.to_vec();
        plans.push(plan);
        let after = estimate_under_plan(graph, &plans);
        if after.peak_bytes <= budget {
            return Some(n);
        }
        // Progress = the global peak shrank, or it *moved* out of this
        // region (identical stacked layers gate each other: chunking
        // layer 1 leaves the global peak at layer 2 — still progress,
        // the next pass attacks the new peak).
        let shrank = (after.peak_bytes as f64)
            < (1.0 - config.min_progress) * current.peak_bytes as f64;
        let moved = in_region && !cand.plan.contains(after.peak_node)
            && after.peak_node != current.peak_node;
        if shrank || moved {
            if progressing.is_none() {
                progressing = Some(n);
            }
            // When the global budget is gated by *other* regions, deeper
            // chunks here only cost speed. Stop at the smallest n whose
            // local Eq. 2 footprint takes no more than half the budget —
            // the deepening post-pass in `autochunk` can revisit later.
            if footprint_ok.is_none() && fp <= budget / 2 {
                footprint_ok = Some(n);
            }
            // Knee: once whole inputs/outputs dominate, doubling n stops
            // shrinking the footprint — deeper chunks are pure speed loss.
            if knee.is_none() {
                if let Some(pf) = prev_fp {
                    if (fp as f64) > 0.85 * pf as f64 {
                        knee = Some(n / 2);
                    }
                }
            }
        }
        prev_fp = Some(fp);
        n *= 2;
    }
    // priority: meets-local-budget-share → footprint knee → first
    // progressing count
    footprint_ok
        .or(knee.filter(|_| progressing.is_some()))
        .or(progressing)
}

/// The region's chunked footprint per Eq. 2: chunk inputs + outputs whole,
/// largest interior activation divided by the chunk count. Constant-derived
/// inputs (broadcasts of scalars) are stride-0 views at runtime and count
/// as zero bytes.
pub fn eq2_footprint(graph: &Graph, plan: &ChunkPlan) -> usize {
    let constant = crate::passes::search::const_derived(graph);
    let inputs_bytes: usize = plan
        .chunk_inputs
        .iter()
        .filter(|&&(i, _)| !constant[i])
        .map(|&(i, _)| graph.node(i).byte_size())
        .sum();
    let outputs_bytes: usize = plan
        .outputs
        .iter()
        .map(|&(o, _)| graph.node(o).byte_size())
        .sum();
    let output_set: std::collections::HashSet<_> =
        plan.outputs.iter().map(|&(o, _)| o).collect();
    let interior_scaled = plan
        .region
        .iter()
        .filter(|r| !output_set.contains(r))
        .map(|&r| {
            let dim = plan.node_dims[&r];
            let node = graph.node(r);
            let extent = node.shape[dim];
            let step = extent.div_ceil(plan.n_chunks);
            node.byte_size() * step / extent
        })
        .max()
        .unwrap_or(0);
    inputs_bytes + outputs_bytes + interior_scaled
}

/// Pick the minimum-cost candidate that makes progress toward `budget`.
/// This is one DP step; the beam driver in `passes::autochunk` explores
/// multiple alternatives across passes.
pub fn select_chunks(
    graph: &Graph,
    candidates: &[ChunkCandidate],
    existing: &[ChunkPlan],
    budget: usize,
    config: &SelectConfig,
) -> Option<ScoredChunk> {
    rank_candidates(graph, candidates, existing, budget, config)
        .into_iter()
        .next()
}

/// All viable candidates with chosen chunk counts, sorted by cost
/// ascending (used by the beam driver).
pub fn rank_candidates(
    graph: &Graph,
    candidates: &[ChunkCandidate],
    existing: &[ChunkPlan],
    budget: usize,
    config: &SelectConfig,
) -> Vec<ScoredChunk> {
    // Scoring a candidate re-runs the estimator several times (once per
    // probed chunk count) and candidates are independent, so they fan out
    // over the worker pool. `parallel_map` returns results in candidate
    // order and the sort below is stable, so ranking stays deterministic
    // at every pool width.
    let scored_per_cand = pool::parallel_map(candidates.len(), |ci| {
        let cand = &candidates[ci];
        let n = choose_n_chunks(graph, cand, existing, budget, config)?;
        let mut plan = cand.plan.clone();
        plan.n_chunks = n;
        let cost = score(graph, &plan, config);
        let mut plans = existing.to_vec();
        plans.push(plan.clone());
        let peak_after = estimate_under_plan(graph, &plans).peak_bytes;
        let meets_budget = peak_after <= budget;
        let eq2_footprint = eq2_footprint(graph, &plan);
        Some(ScoredChunk {
            plan,
            cost,
            meets_budget,
            peak_after,
            eq2_footprint,
        })
    });
    let mut scored: Vec<ScoredChunk> = scored_per_cand.into_iter().flatten().collect();
    // Eq. 11 is *constrained* minimization: candidates satisfying the
    // memory cap dominate and rank by cost; among the rest (partial
    // progress toward a budget no single chunk can reach), the one that
    // gets closest matters more than its cost — a cheap chunk that leaves
    // the peak high permanently occupies its region and strands the DP.
    scored.sort_by(|a, b| {
        b.meets_budget.cmp(&a.meets_budget).then_with(|| {
            if a.meets_budget {
                a.cost.partial_cmp(&b.cost).unwrap()
            } else {
                // peak_after is gated by *other* regions and often ties or
                // even favors locally-attractive-but-stranding plans;
                // the Eq. 2 footprint of the region is the honest measure.
                a.eq2_footprint
                    .cmp(&b.eq2_footprint)
                    .then(a.peak_after.cmp(&b.peak_after))
                    .then(a.cost.partial_cmp(&b.cost).unwrap())
            }
        })
    });
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::passes::estimate::estimate;
    use crate::passes::search::{search_chunks, SearchConfig};
    use crate::tensor::ops::BinaryOp;

    fn attn_graph(s: usize, d: usize) -> crate::ir::Graph {
        let mut b = GraphBuilder::new("attn");
        let x = b.input("x", &[s, d]);
        let wq = b.param("wq", &[d, d]);
        let wk = b.param("wk", &[d, d]);
        let wv = b.param("wv", &[d, d]);
        let q = b.matmul(x, wq);
        let k = b.matmul(x, wk);
        let v = b.matmul(x, wv);
        let kt = b.transpose(k, &[1, 0]);
        let scores = b.matmul(q, kt);
        let scaled = b.binary_scalar(BinaryOp::Mul, scores, 0.125);
        let probs = b.softmax(scaled, 1);
        let out = b.matmul(probs, v);
        b.finish(vec![out])
    }

    #[test]
    fn selection_meets_budget() {
        let g = attn_graph(256, 16);
        let p = estimate(&g);
        let budget = p.peak_bytes / 3;
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let choice = select_chunks(&g, &cands, &[], budget, &SelectConfig::default())
            .expect("no viable chunk");
        let peak = estimate_under_plan(&g, &[choice.plan.clone()]).peak_bytes;
        assert!(
            peak <= budget || peak < p.peak_bytes,
            "no progress: {} vs budget {} (base {})",
            peak,
            budget,
            p.peak_bytes
        );
        assert!(choice.plan.n_chunks >= 2);
    }

    #[test]
    fn smaller_budget_needs_more_chunks() {
        let g = attn_graph(256, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let cfg = SelectConfig::default();
        let loose = select_chunks(&g, &cands, &[], p.peak_bytes * 6 / 10, &cfg).unwrap();
        let tight = select_chunks(&g, &cands, &[], p.peak_bytes * 25 / 100, &cfg).unwrap();
        assert!(
            tight.plan.n_chunks >= loose.plan.n_chunks,
            "tight {} < loose {}",
            tight.plan.n_chunks,
            loose.plan.n_chunks
        );
    }

    #[test]
    fn stride_term_prefers_outer_dims() {
        // two otherwise-identical plans, one chunking dim 0 and one dim 1:
        // dim 0 (outer) must score lower when the stride term is on.
        let g = attn_graph(128, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let cfg = SelectConfig::default();
        let mut outer: Option<f64> = None;
        let mut inner: Option<f64> = None;
        for c in &cands {
            let mut plan = c.plan.clone();
            plan.n_chunks = 8;
            let s = score(&g, &plan, &cfg);
            let d = plan.outputs[0].1;
            if d == 0 {
                outer = Some(outer.map_or(s, |x: f64| x.min(s)));
            } else {
                inner = Some(inner.map_or(s, |x: f64| x.min(s)));
            }
        }
        if let (Some(o), Some(i)) = (outer, inner) {
            assert!(o < i, "outer {o} not cheaper than inner {i}");
        }
    }

    #[test]
    fn density_term_penalizes_many_chunks() {
        let g = attn_graph(128, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let cfg = SelectConfig::default();
        let mut p2 = cands[0].plan.clone();
        p2.n_chunks = 2;
        let mut p64 = cands[0].plan.clone();
        p64.n_chunks = 64;
        assert!(score(&g, &p2, &cfg) < score(&g, &p64, &cfg));
    }

    #[test]
    fn ablation_flags_change_ranking_inputs() {
        let g = attn_graph(128, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let mut plan = cands[0].plan.clone();
        plan.n_chunks = 8;
        let full = score(&g, &plan, &SelectConfig::default());
        let no_density = score(
            &g,
            &plan,
            &SelectConfig {
                use_density: false,
                ..Default::default()
            },
        );
        let no_stride = score(
            &g,
            &plan,
            &SelectConfig {
                use_stride: false,
                ..Default::default()
            },
        );
        assert!(no_density < full);
        assert!(no_stride < full);
    }

    #[test]
    fn rank_is_sorted() {
        let g = attn_graph(128, 16);
        let p = estimate(&g);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        let ranked = rank_candidates(&g, &cands, &[], p.peak_bytes / 2, &SelectConfig::default());
        for w in ranked.windows(2) {
            // budget-satisfying candidates first; within the satisfying
            // prefix ascending cost; within the rest ascending Eq.2
            // footprint (then peak)
            assert!(w[0].meets_budget >= w[1].meets_budget);
            if w[0].meets_budget && w[1].meets_budget {
                assert!(w[0].cost <= w[1].cost);
            } else if !w[0].meets_budget && !w[1].meets_budget {
                assert!(w[0].eq2_footprint <= w[1].eq2_footprint);
            }
        }
    }
}
