//! Static memory planner: liveness-driven arena layout with exact peak
//! accounting (DESIGN.md §12).
//!
//! The interpreter discovers its peak empirically — it allocates a fresh
//! tracked buffer per op and frees on `Drop`. This pass computes the same
//! execution's memory behaviour *at compile time*: per-value liveness over
//! the scheduled [`Graph`] (and over each [`ChunkPlan`] region body),
//! offset assignment into a single arena via best-fit interval allocation
//! with buffer reuse, zero-copy aliasing for shape-preserving views
//! (transpose/slice/contiguous-reshape/f32-convert/broadcast), and true
//! in-place computation for elementwise ops whose operand dies at the op
//! (the "elementwise-into-dead-operand" rule, with the use-twice and
//! live-alias hazards rejected conservatively).
//!
//! The resulting [`MemPlan`] is a *script*: per-node actions plus explicit
//! release lists. The arena executor ([`crate::exec::arena`]) follows the
//! script verbatim, so the planner's `planned_peak_bytes` equals the
//! runtime [`crate::tensor::Arena`] high-water mark exactly — the property
//! `rust/tests/memplan_exact.rs` pins — and `admission_bytes` is a sound,
//! *tight* admission price that replaces the pessimistic
//! [`crate::passes::estimate::CostQuote`] in the serve engine (the quote
//! stays as a cross-check ceiling).

use crate::ir::{Graph, Node, NodeId, Op};
use crate::plan::{region_owner, region_triggers, ChunkPlan};
use crate::tensor::{broadcast_shapes, contiguous_strides, numel, DType, SlotSpec};
use std::collections::HashMap;
use std::sync::OnceLock;

/// What the arena executor does for one value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueAction {
    /// Caller-provided binding (graph input/param; region-external value
    /// in a lane scope). No arena involvement.
    External,
    /// Produced by its owning chunk region at that region's trigger
    /// point (outer scope only).
    Region,
    /// Zero-copy view of input 0's storage root.
    Alias,
    /// Fresh arena allocation into `slot`.
    Materialize { slot: usize },
    /// Elementwise op computed in place into the dying operand at
    /// `inputs[pos]`, inheriting its slot.
    InPlace { pos: usize },
}

// ------------------------------------------------------- placement tiers

/// Spill-tier configuration: modeled slow-tier bandwidth in GB/s.
/// `None` (the default) disables placement search entirely — planning is
/// bitwise identical to the legacy path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpillParams {
    /// Slow-tier bandwidth in GB/s; must be > 0 when present.
    pub gbps: f64,
}

/// Reads `AUTOCHUNK_SPILL_GBPS` once per process. Unset, unparsable, or
/// non-positive values disable the spill tier. Tests and benches that
/// need both legs in one process pass explicit params to
/// [`plan_memory_with`] instead of the env.
pub fn spill_params_from_env() -> Option<SpillParams> {
    static CELL: OnceLock<Option<f64>> = OnceLock::new();
    let gbps = *CELL.get_or_init(|| {
        std::env::var("AUTOCHUNK_SPILL_GBPS")
            .ok()
            .and_then(|s| s.trim().parse::<f64>().ok())
            .filter(|&g| g > 0.0 && g.is_finite())
    });
    gbps.map(|gbps| SpillParams { gbps })
}

/// How a spilled value comes back at its restore point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpillKind {
    /// Copy the bytes to the slow tier at the spill point; copy them back
    /// into the same arena slot at the restore point. Costs
    /// `2·bytes ÷ gbps` of modeled transfer time.
    Offload,
    /// Drop the value at the spill point; re-execute its node (all inputs
    /// still live) into the same arena slot at the restore point. Costs
    /// the node's FLOPs at the modeled recompute rate.
    Recompute,
}

/// One placement decision: value `value` (arena slot `slot`, `bytes`
/// planned bytes) leaves the fast tier after position `spill_after`
/// executes and is restored before position `restore_before` executes.
/// Positions are outer node ids; the executor runs restores at the top
/// of a position and spills at its very end (after releases and region
/// triggers), which is exactly the order the planner's replay prices.
#[derive(Clone, Debug)]
pub struct SpillDecision {
    pub value: NodeId,
    pub slot: usize,
    pub bytes: usize,
    pub spill_after: NodeId,
    pub restore_before: NodeId,
    pub kind: SpillKind,
    /// Modeled latency of this decision in microseconds (transfer or
    /// recompute), for CostQuote pricing and reports.
    pub cost_us: f64,
}

/// Memory plan for one chunk-region body, sized at the full chunk step —
/// every concurrent lane of the region gets its own sub-arena built from
/// these slots, which is what makes the concurrency governor's degree
/// math exact.
#[derive(Clone, Debug)]
pub struct RegionMemPlan {
    /// Per region node (in `plan.region` order): its action.
    pub actions: Vec<(NodeId, ValueAction)>,
    /// Parallel to `actions`: region-internal value ids to drop after
    /// each node executes (within one lane iteration).
    pub release_after: Vec<Vec<NodeId>>,
    /// Lane sub-arena slots.
    pub slots: Vec<SlotSpec>,
    /// Exact lane sub-arena peak (== each lane arena's high-water mark).
    pub lane_bytes: usize,
    /// Lane peak plus the worst transient kernel workspace — the price
    /// of one in-flight iteration for admission/governor math.
    pub lane_admission: usize,
    /// Outer-arena slots of the output accumulators (parallel to
    /// `plan.outputs`), acquired at the region trigger.
    pub accum_slots: Vec<usize>,
    /// Outer-arena slots for materialized pass-input copies (parallel to
    /// `plan.pass_inputs`; `None` = passed as-is), held for the region's
    /// duration.
    pub pass_slots: Vec<Option<usize>>,
    /// Outer values whose last use was this region (its consumed external
    /// inputs and any dead outputs), released after the region executes —
    /// kept separate from the per-node release lists so the executor
    /// replays the planner's exact acquire/release order.
    pub post_releases: Vec<NodeId>,
}

/// The planner's output: a per-node action script with explicit release
/// lists, the arena layout, and exact/sound memory numbers.
#[derive(Clone, Debug)]
pub struct MemPlan {
    /// Per node id: what the executor does for it (outer schedule).
    pub actions: Vec<ValueAction>,
    /// Per node id: value ids whose last use has passed once that node
    /// has executed (region-phase releases are in
    /// [`RegionMemPlan::post_releases`] so ordering is exact).
    pub release_after: Vec<Vec<NodeId>>,
    /// Outer arena slots (offset + planned bytes).
    pub slots: Vec<SlotSpec>,
    /// Exact peak of live planned bytes in the outer arena — equals the
    /// runtime arena high-water mark.
    pub planned_peak_bytes: usize,
    /// Contiguous-slab footprint (max `offset + bytes` over slots); can
    /// exceed `planned_peak_bytes` through fragmentation.
    pub footprint_bytes: usize,
    /// Values that received a fresh slot (reuse ratio denominator is
    /// `slots.len()`).
    pub values_materialized: usize,
    /// Elementwise ops computed into a dead operand.
    pub inplace_count: usize,
    /// Values served as zero-copy aliases.
    pub alias_count: usize,
    /// Graph input bytes, live for the whole run (callers hold inputs).
    /// Excludes persistent inputs — they are resident across runs and
    /// priced separately (`persistent_bytes`).
    pub input_bytes: usize,
    /// Bytes of persistent (cross-execution) inputs such as KV caches.
    /// Outside the per-run arena and outside `admission_bytes`; the serve
    /// engine charges them once per bound cache as resident state. For
    /// paged decode graphs this is block granularity — the blocks the
    /// request holds at this cache length, not bucket capacity
    /// (DESIGN.md §14).
    pub persistent_bytes: usize,
    /// Number of persistent inputs (monolithic caches: `2·layers`; paged
    /// decode: `2·layers·nblk` — one per bound block tensor).
    pub persistent_inputs: usize,
    /// Sound admission price of one serial execution: inputs + arena live
    /// + transient kernel workspace, maximized over the schedule (one
    /// lane per region in flight).
    pub admission_base: usize,
    /// Accepted spill/recompute placement decisions in schedule order.
    /// Empty when the spill tier is disabled (the default) — in which
    /// case every other field is bitwise identical to legacy planning.
    pub spills: Vec<SpillDecision>,
    /// Bytes moved across the slow tier (out + back) over all offload
    /// decisions.
    pub spill_transfer_bytes: usize,
    /// FLOPs re-executed by recompute decisions.
    pub spill_recompute_flops: usize,
    /// Peak reduction vs legacy planning (legacy peak − planned peak).
    pub spill_saved_bytes: usize,
    /// Per chunk plan: the lane memory plan.
    pub regions: Vec<RegionMemPlan>,
}

impl MemPlan {
    /// Admission price with `degree` chunk iterations in flight: each
    /// extra lane costs the worst region's `lane_admission`.
    pub fn admission_bytes(&self, degree: usize) -> usize {
        self.admission_base + degree.saturating_sub(1) * self.max_lane_admission()
    }

    /// Price of one extra in-flight chunk iteration (0 when unchunked).
    pub fn max_lane_admission(&self) -> usize {
        self.regions.iter().map(|r| r.lane_admission).max().unwrap_or(0)
    }

    /// Buffer-reuse ratio: materialized values per arena slot (>= 1; 1.0
    /// means no slot ever served two values).
    pub fn reuse_ratio(&self) -> f64 {
        self.values_materialized as f64 / self.slots.len().max(1) as f64
    }
}

// ---------------------------------------------------------------- views

/// Symbolic mirror of [`crate::tensor::Tensor`]'s view math (shape,
/// strides, offset-zero flag), so the planner's contiguity and aliasing
/// decisions match the runtime exactly.
#[derive(Clone, Debug)]
struct ViewState {
    shape: Vec<usize>,
    strides: Vec<isize>,
    /// True while the view still starts at its buffer's offset 0 — an
    /// in-place target must cover the whole root buffer.
    offset_zero: bool,
}

impl ViewState {
    fn contiguous(shape: &[usize]) -> ViewState {
        ViewState {
            shape: shape.to_vec(),
            strides: contiguous_strides(shape),
            offset_zero: true,
        }
    }

    fn is_contiguous(&self) -> bool {
        self.strides == contiguous_strides(&self.shape)
    }

    fn permute(&self, perm: &[usize]) -> ViewState {
        ViewState {
            shape: perm.iter().map(|&p| self.shape[p]).collect(),
            strides: perm.iter().map(|&p| self.strides[p]).collect(),
            offset_zero: self.offset_zero,
        }
    }

    fn slice_axis(&self, axis: usize, start: usize, len: usize) -> ViewState {
        let mut shape = self.shape.clone();
        shape[axis] = len;
        ViewState {
            shape,
            strides: self.strides.clone(),
            offset_zero: self.offset_zero && (start == 0 || self.strides[axis] == 0),
        }
    }

    fn broadcast_to(&self, target: &[usize]) -> ViewState {
        let pad = target.len() - self.shape.len();
        let mut strides = vec![0isize; target.len()];
        for i in 0..target.len() {
            if i >= pad {
                let s = self.shape[i - pad];
                strides[i] = if s == target[i] { self.strides[i - pad] } else { 0 };
            }
        }
        ViewState {
            shape: target.to_vec(),
            strides,
            offset_zero: self.offset_zero,
        }
    }

    /// Contiguous reshape alias (caller checked `is_contiguous`).
    fn reshape(&self, new_shape: &[usize]) -> ViewState {
        ViewState {
            shape: new_shape.to_vec(),
            strides: contiguous_strides(new_shape),
            offset_zero: self.offset_zero,
        }
    }

    fn has_broadcast_stride(&self) -> bool {
        self.strides
            .iter()
            .zip(&self.shape)
            .any(|(&s, &d)| s == 0 && d > 1)
    }

    fn numel(&self) -> usize {
        numel(&self.shape)
    }
}

// ------------------------------------------------------------ allocator

/// One entry of the planner's byte-exact event log (recorded only when
/// the spill tier is enabled). Replaying the log with a set of
/// [`SpillDecision`]s spliced in reproduces `planned_peak_bytes` and
/// `admission_base` exactly — the same invariant the runtime arena obeys.
#[derive(Clone, Copy, Debug)]
enum PlanEvent {
    /// Live bytes grew by this much (a slot allocation).
    Alloc(usize),
    /// Live bytes shrank by this much (a slot free).
    Free(usize),
    /// Admission sample: `admission = max(admission, inputs + live + extra)`
    /// where `extra` is a transient workspace or lane-admission bound.
    Probe(usize),
}

/// Best-fit interval allocator over a growable arena. Distinct
/// (offset, bytes) pairs become slots; re-allocating an interval a dead
/// value vacated reuses its slot id (and, at runtime, its storage).
#[derive(Default)]
struct Allocator {
    /// Sorted disjoint free gaps (offset, len) below `end`.
    free: Vec<(usize, usize)>,
    end: usize,
    slot_ids: HashMap<(usize, usize), usize>,
    slots: Vec<SlotSpec>,
    live_sum: usize,
    peak: usize,
    /// Record Alloc/Free events (spill-tier planning only).
    trace_on: bool,
    trace: Vec<PlanEvent>,
}

impl Allocator {
    /// Allocate `bytes`, returning the slot id.
    fn alloc(&mut self, bytes: usize) -> usize {
        debug_assert!(bytes > 0, "zero-byte slot");
        // Best fit: the smallest gap that holds `bytes`; ties break to
        // the lowest offset. First fit (arena end) when nothing fits.
        let mut best: Option<usize> = None;
        for (i, &(off, len)) in self.free.iter().enumerate() {
            if len >= bytes {
                let better = match best {
                    None => true,
                    Some(b) => {
                        let (boff, blen) = self.free[b];
                        len < blen || (len == blen && off < boff)
                    }
                };
                if better {
                    best = Some(i);
                }
            }
        }
        let offset = match best {
            Some(i) => {
                let (off, len) = self.free[i];
                if len == bytes {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + bytes, len - bytes);
                }
                off
            }
            None => {
                let off = self.end;
                self.end += bytes;
                off
            }
        };
        self.live_sum += bytes;
        self.peak = self.peak.max(self.live_sum);
        if self.trace_on {
            self.trace.push(PlanEvent::Alloc(bytes));
        }
        let existing = self.slot_ids.get(&(offset, bytes)).copied();
        match existing {
            Some(id) => id,
            None => {
                let id = self.slots.len();
                self.slot_ids.insert((offset, bytes), id);
                self.slots.push(SlotSpec { offset, bytes });
                id
            }
        }
    }

    /// Free a slot's interval, merging adjacent gaps.
    fn free_slot(&mut self, slot: usize) {
        let SlotSpec { offset, bytes } = self.slots[slot];
        self.live_sum -= bytes;
        if self.trace_on {
            self.trace.push(PlanEvent::Free(bytes));
        }
        let pos = self.free.partition_point(|&(o, _)| o < offset);
        self.free.insert(pos, (offset, bytes));
        if pos + 1 < self.free.len() {
            let (o1, l1) = self.free[pos];
            let (o2, l2) = self.free[pos + 1];
            if o1 + l1 == o2 {
                self.free[pos] = (o1, l1 + l2);
                self.free.remove(pos + 1);
            }
        }
        if pos > 0 {
            let (o0, l0) = self.free[pos - 1];
            let (o1, l1) = self.free[pos];
            if o0 + l0 == o1 {
                self.free[pos - 1] = (o0, l0 + l1);
                self.free.remove(pos);
            }
        }
    }
}

// ---------------------------------------------------------- scope state

/// Per-scope value bookkeeping: storage roots, slots, live alias counts,
/// symbolic views. Indexed by graph node id in both the outer schedule
/// and region-lane scopes.
struct Scope {
    alloc: Allocator,
    root: Vec<NodeId>,
    root_slot: Vec<Option<usize>>,
    root_refs: Vec<usize>,
    view: Vec<Option<ViewState>>,
}

impl Scope {
    fn new(n: usize) -> Scope {
        Scope {
            alloc: Allocator::default(),
            root: (0..n).collect(),
            root_slot: vec![None; n],
            root_refs: vec![0; n],
            view: vec![None; n],
        }
    }

    /// Bind an external value (input/param or region-external).
    fn bind_external(&mut self, id: NodeId, view: ViewState) {
        self.root[id] = id;
        self.root_slot[id] = None;
        self.root_refs[id] = 1;
        self.view[id] = Some(view);
    }

    /// Bind a freshly materialized value into a new slot.
    fn bind_slot(&mut self, id: NodeId, slot: usize, view: ViewState) {
        self.root[id] = id;
        self.root_slot[id] = Some(slot);
        self.root_refs[id] = 1;
        self.view[id] = Some(view);
    }

    /// Bind an alias of `of`'s storage root.
    fn bind_alias(&mut self, id: NodeId, of: NodeId, view: ViewState) {
        let r = self.root[of];
        self.root[id] = r;
        self.root_refs[r] += 1;
        self.view[id] = Some(view);
    }

    /// Drop one value reference; frees the root's slot at zero refs.
    fn release_value(&mut self, id: NodeId) {
        let r = self.root[id];
        debug_assert!(self.root_refs[r] > 0, "double release of value {id}");
        self.root_refs[r] -= 1;
        if self.root_refs[r] == 0 {
            if let Some(slot) = self.root_slot[r].take() {
                self.alloc.free_slot(slot);
            }
        }
    }

    /// In-place transfer: `id` takes over `operand`'s root and slot; the
    /// operand's own reference ends without freeing (net zero).
    fn bind_inplace(&mut self, id: NodeId, operand: NodeId, view: ViewState) {
        let r = self.root[operand];
        debug_assert_eq!(self.root_refs[r], 1, "in-place with live aliases");
        self.root[id] = r;
        self.view[id] = Some(view);
        // refs stay 1: the operand's reference becomes the output's.
    }

    /// True if `operand` qualifies as an in-place target producing
    /// `out_shape`: f32, a contiguous whole-buffer view of a slot-backed
    /// root with no other live aliases, dying at this node (its remaining
    /// uses all being this node's `multiplicity` occurrences).
    fn inplace_ok(
        &self,
        graph: &Graph,
        refcount: &[usize],
        operand: NodeId,
        out_shape: &[usize],
        multiplicity: usize,
    ) -> bool {
        if graph.node(operand).dtype != DType::F32 {
            return false;
        }
        let Some(v) = &self.view[operand] else {
            return false;
        };
        if v.shape != out_shape || !v.is_contiguous() || !v.offset_zero {
            return false;
        }
        let r = self.root[operand];
        let Some(slot) = self.root_slot[r] else {
            return false; // external storage is never written in place
        };
        // The view must cover the whole slot (no partial-buffer targets).
        if self.alloc.slots[slot].bytes != v.numel() * 4 {
            return false;
        }
        self.root_refs[r] == 1 && refcount[operand] == multiplicity
    }
}

// ------------------------------------------------------- node decisions

/// Effective shapes for a scope: outer = node shapes; lanes scale the
/// chunk dim to the step.
type EffShapes = Vec<Vec<usize>>;

/// Decide and apply the action for one node, returning the action and the
/// node's transient tracked-workspace bound in bytes. Mirrors the arena
/// executor's dispatch exactly — both sides are generated from this
/// table's rules.
fn process_node(
    graph: &Graph,
    node: &Node,
    eff: &EffShapes,
    scope: &mut Scope,
    refcount: &[usize],
    stats: &mut PlanStats,
) -> (ValueAction, usize) {
    let id = node.id;
    let out_shape = &eff[id];
    let in_view = |scope: &Scope, pos: usize| -> ViewState {
        scope.view[node.inputs[pos]]
            .clone()
            .unwrap_or_else(|| panic!("planner: value {} not live for node {id}", node.inputs[pos]))
    };
    let materialize = |scope: &mut Scope, stats: &mut PlanStats, bytes: usize, view: ViewState| {
        let slot = scope.alloc.alloc(bytes);
        scope.bind_slot(id, slot, view);
        stats.materialized += 1;
        ValueAction::Materialize { slot }
    };
    let alias = |scope: &mut Scope, stats: &mut PlanStats, of_pos: usize, view: ViewState| {
        scope.bind_alias(id, node.inputs[of_pos], view);
        stats.aliased += 1;
        ValueAction::Alias
    };

    match &node.op {
        Op::Input | Op::Param => unreachable!("leaves are pre-bound"),
        Op::Const(_) | Op::Iota { .. } => {
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), 0)
        }
        Op::Transpose { perm } => {
            let v = in_view(scope, 0).permute(perm);
            (alias(scope, stats, 0, v), 0)
        }
        Op::Slice { axis, start, .. } => {
            let v = in_view(scope, 0).slice_axis(*axis, *start, out_shape[*axis]);
            (alias(scope, stats, 0, v), 0)
        }
        Op::Broadcast { dims } => {
            let iv = in_view(scope, 0);
            let mut reshaped = vec![1usize; out_shape.len()];
            for (i, &d) in dims.iter().enumerate() {
                reshaped[d] = iv.shape[i];
            }
            if iv.is_contiguous() {
                let v = iv.reshape(&reshaped).broadcast_to(out_shape);
                (alias(scope, stats, 0, v), 0)
            } else {
                // the runtime reshape materializes the input copy
                let v = ViewState::contiguous(&reshaped).broadcast_to(out_shape);
                (materialize(scope, stats, iv.numel() * 4, v), 0)
            }
        }
        Op::Reshape => {
            let iv = in_view(scope, 0);
            if iv.is_contiguous() {
                let v = iv.reshape(out_shape);
                (alias(scope, stats, 0, v), 0)
            } else {
                let v = ViewState::contiguous(out_shape);
                (materialize(scope, stats, numel(out_shape) * 4, v), 0)
            }
        }
        Op::Convert => {
            let iv = in_view(scope, 0);
            let src_f32 = graph.node(node.inputs[0]).dtype == DType::F32;
            if src_f32 && iv.is_contiguous() {
                (alias(scope, stats, 0, iv), 0)
            } else {
                let v = ViewState::contiguous(out_shape);
                (materialize(scope, stats, numel(out_shape) * 4, v), 0)
            }
        }
        Op::Unary(_) => {
            let operand = node.inputs[0];
            if scope.inplace_ok(graph, refcount, operand, out_shape, 1) {
                let v = ViewState::contiguous(out_shape);
                scope.bind_inplace(id, operand, v);
                stats.inplace += 1;
                (ValueAction::InPlace { pos: 0 }, 0)
            } else {
                let v = ViewState::contiguous(out_shape);
                (materialize(scope, stats, numel(out_shape) * 4, v), 0)
            }
        }
        Op::Binary(_) => {
            let multiplicity = |operand: NodeId| -> usize {
                node.inputs.iter().filter(|&&i| i == operand).count()
            };
            let mut chosen: Option<usize> = None;
            for pos in 0..2 {
                let operand = node.inputs[pos];
                if pos == 1 && node.inputs[0] == node.inputs[1] {
                    break; // self-op: pos 0 already covers it
                }
                if scope.inplace_ok(graph, refcount, operand, out_shape, multiplicity(operand)) {
                    chosen = Some(pos);
                    break;
                }
            }
            match chosen {
                Some(pos) => {
                    let v = ViewState::contiguous(out_shape);
                    scope.bind_inplace(id, node.inputs[pos], v);
                    stats.inplace += 1;
                    (ValueAction::InPlace { pos }, 0)
                }
                None => {
                    let v = ViewState::contiguous(out_shape);
                    (materialize(scope, stats, numel(out_shape) * 4, v), 0)
                }
            }
        }
        Op::MatMul => {
            let ws = matmul_transients(&in_view(scope, 0), &in_view(scope, 1));
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::DotGeneral {
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
        } => {
            // Mirrors the executor's canonicalization: each side permutes
            // to [batch, free, contract] (lhs) / [batch, contract, free]
            // (rhs); a copy is paid iff the permuted view is
            // non-contiguous.
            let side = |view: &ViewState,
                        batch: &[usize],
                        contract: &[usize],
                        contract_first: bool| {
                let rank = view.shape.len();
                let free: Vec<usize> = (0..rank)
                    .filter(|d| !batch.contains(d) && !contract.contains(d))
                    .collect();
                let mut perm = batch.to_vec();
                if contract_first {
                    perm.extend(contract.iter().copied());
                    perm.extend(&free);
                } else {
                    perm.extend(&free);
                    perm.extend(contract.iter().copied());
                }
                let pv = view.permute(&perm);
                if pv.is_contiguous() {
                    0
                } else {
                    pv.numel() * 4
                }
            };
            let a = in_view(scope, 0);
            let b = in_view(scope, 1);
            let ws = side(&a, lhs_batch, lhs_contract, false)
                + side(&b, rhs_batch, rhs_contract, true);
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Reduce { axis, .. } => {
            let iv = in_view(scope, 0);
            let perm = axis_last_perm(iv.shape.len(), *axis);
            let pv = iv.permute(&perm);
            let ws = if pv.is_contiguous() { 0 } else { pv.numel() * 4 };
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Softmax { axis } => {
            let iv = in_view(scope, 0);
            let perm = axis_last_perm(iv.shape.len(), *axis);
            let pv = iv.permute(&perm);
            let mut ws = if pv.is_contiguous() { 0 } else { pv.numel() * 4 };
            if *axis != iv.shape.len() - 1 {
                // non-innermost axis: the permuted-layout scratch the
                // kernel fills before the inverse-permuted copy out
                ws += iv.numel() * 4;
            }
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Concat { .. } => {
            let mut ws = 0usize;
            for pos in 0..node.inputs.len() {
                let pv = in_view(scope, pos);
                if !pv.is_contiguous() {
                    ws += pv.numel() * 4;
                }
            }
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Gather => {
            let tv = in_view(scope, 0);
            let ws = if tv.is_contiguous() { 0 } else { tv.numel() * 4 };
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Conv2d { .. } => {
            let xv = in_view(scope, 0);
            let wv = in_view(scope, 1);
            let w_shape = &eff[node.inputs[1]];
            let cols_width = w_shape[1] * w_shape[2] * w_shape[3];
            let cols_rows = out_shape[0] * out_shape[2] * out_shape[3];
            let cout = w_shape[0];
            let mut ws = cols_rows * cols_width * 4; // im2col matrix
            ws += cols_rows * cout * 4; // pre-permute GEMM output
            if !xv.is_contiguous() {
                ws += xv.numel() * 4;
            }
            // weight reshape copy iff non-contiguous, then the permuted
            // [width, cout] operand materialized inside the matmul
            let wt = if wv.is_contiguous() {
                wv.reshape(&[cout, cols_width])
            } else {
                ws += wv.numel() * 4;
                ViewState::contiguous(&[cout, cols_width])
            };
            let wt_perm = wt.permute(&[1, 0]);
            if !wt_perm.is_contiguous() {
                ws += wt_perm.numel() * 4;
            }
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::AvgPool2x | Op::Upsample2x => {
            let xv = in_view(scope, 0);
            let ws = if xv.is_contiguous() { 0 } else { xv.numel() * 4 };
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::FusedAttention { .. } => {
            let q = in_view(scope, 0);
            let k = in_view(scope, 1);
            let vv = in_view(scope, 2);
            let mut ws = fused_attention_transients(&q, &k, &vv);
            if node.inputs.len() > 3 {
                // optional q_pos: the kernel materializes it iff strided
                let pv = in_view(scope, 3);
                if !pv.is_contiguous() {
                    ws += pv.numel() * 4;
                }
            }
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), ws)
        }
        Op::Opaque { .. } => {
            // analysis-only; the executor refuses it like the interpreter
            let v = ViewState::contiguous(out_shape);
            (materialize(scope, stats, numel(out_shape) * 4, v), 0)
        }
    }
}

/// Permutation that moves `axis` last (the reduce/softmax row layout).
fn axis_last_perm(rank: usize, axis: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..rank).filter(|&i| i != axis).collect();
    perm.push(axis);
    perm
}

/// Tracked transient bytes of a matmul: each operand is broadcast to the
/// full batch and materialized contiguously iff that is not a no-op —
/// including batch *expansion*, which the pessimistic quote under-models.
fn matmul_transients(a: &ViewState, b: &ViewState) -> usize {
    let ar = a.shape.len();
    let br = b.shape.len();
    let (m, k) = (a.shape[ar - 2], a.shape[ar - 1]);
    let n = b.shape[br - 1];
    let batch_shape = broadcast_shapes(&a.shape[..ar - 2], &b.shape[..br - 2]);
    let mut a_full = batch_shape.clone();
    a_full.extend_from_slice(&[m, k]);
    let mut b_full = batch_shape.clone();
    b_full.extend_from_slice(&[b.shape[br - 2], n]);
    let mut ws = 0usize;
    let ab = a.broadcast_to(&a_full);
    if !ab.is_contiguous() {
        ws += numel(&a_full) * 4;
    }
    let bb = b.broadcast_to(&b_full);
    if !bb.is_contiguous() {
        ws += numel(&b_full) * 4;
    }
    ws
}

/// Tracked transient bytes of fused attention: q/k/v broadcast to the
/// full batch and materialized iff not already contiguous at full shape.
fn fused_attention_transients(q: &ViewState, k: &ViewState, v: &ViewState) -> usize {
    let rank = q.shape.len();
    let (sq, d) = (q.shape[rank - 2], q.shape[rank - 1]);
    let skv = k.shape[k.shape.len() - 2];
    let dv = v.shape[v.shape.len() - 1];
    let batch_shape = broadcast_shapes(
        &broadcast_shapes(&q.shape[..rank - 2], &k.shape[..k.shape.len() - 2]),
        &v.shape[..v.shape.len() - 2],
    );
    let full = |tail: [usize; 2]| {
        let mut s = batch_shape.clone();
        s.extend_from_slice(&tail);
        s
    };
    let mut ws = 0usize;
    for (view, tail) in [(q, [sq, d]), (k, [skv, d]), (v, [skv, dv])] {
        let fs = full(tail);
        let bv = view.broadcast_to(&fs);
        if !bv.is_contiguous() {
            ws += numel(&fs) * 4;
        }
    }
    ws
}

#[derive(Default)]
struct PlanStats {
    materialized: usize,
    aliased: usize,
    inplace: usize,
}

// ------------------------------------------------------------- planning

/// Compute the memory plan for `graph` under `plans` (empty = unchunked).
/// Spill-tier behaviour comes from `AUTOCHUNK_SPILL_GBPS` (default: off).
pub fn plan_memory(graph: &Graph, plans: &[ChunkPlan]) -> MemPlan {
    plan_memory_with(graph, plans, spill_params_from_env())
}

/// [`plan_memory`] with explicit spill-tier parameters. `None` is the
/// legacy planner, bitwise. `Some` runs legacy planning plus a placement
/// search over the recorded event log: each materialized outer value may
/// be offloaded to the slow tier or recomputed across a gap between uses,
/// accepted greedily while the replayed peak/admission strictly improve.
pub fn plan_memory_with(
    graph: &Graph,
    plans: &[ChunkPlan],
    spill: Option<SpillParams>,
) -> MemPlan {
    let users = graph.users();
    let owner = region_owner(plans, graph.len());
    let triggers = region_triggers(plans);

    let mut refcount: Vec<usize> = users.iter().map(|u| u.len()).collect();
    for &o in &graph.outputs {
        refcount[o] += 1;
    }

    // Effective shapes in the outer schedule are the node shapes.
    let eff: EffShapes = graph.nodes.iter().map(|n| n.shape.clone()).collect();

    let mut scope = Scope::new(graph.len());
    scope.alloc.trace_on = spill.is_some();
    let mut stats = PlanStats::default();
    let mut actions: Vec<ValueAction> = vec![ValueAction::External; graph.len()];
    let mut release_after: Vec<Vec<NodeId>> = vec![Vec::new(); graph.len()];
    let mut regions: Vec<Option<RegionMemPlan>> = vec![None; plans.len()];
    // Spill-search bookkeeping (empty work when the tier is disabled):
    // event-log watermark after each position, per-node transient bound.
    let mut pos_end: Vec<usize> = vec![0; graph.len()];
    let mut node_transient: Vec<usize> = vec![0; graph.len()];

    let input_bytes: usize = graph
        .inputs
        .iter()
        .filter(|&&i| !graph.is_persistent(i))
        .map(|&i| graph.node(i).byte_size())
        .sum();
    let persistent_bytes: usize = graph.persistent_bytes();
    let mut admission_peak = input_bytes;

    let prebound: Vec<bool> = {
        let mut v = vec![false; graph.len()];
        for &i in graph.inputs.iter().chain(graph.params.iter()) {
            v[i] = true;
        }
        v
    };
    for &i in graph.inputs.iter().chain(graph.params.iter()) {
        scope.bind_external(i, ViewState::contiguous(&graph.node(i).shape));
        actions[i] = ValueAction::External;
    }
    for (id, o) in owner.iter().enumerate() {
        if o.is_some() {
            actions[id] = ValueAction::Region;
        }
    }

    for node in &graph.nodes {
        let id = node.id;
        let skip = prebound[id] || owner[id].is_some();
        if !skip {
            let (action, transient) =
                process_node(graph, node, &eff, &mut scope, &refcount, &mut stats);
            actions[id] = action;
            node_transient[id] = transient;
            if scope.alloc.trace_on {
                scope.alloc.trace.push(PlanEvent::Probe(transient));
            }
            admission_peak = admission_peak.max(input_bytes + scope.alloc.live_sum + transient);
            // Dead on arrival (no consumers, not an output).
            if refcount[id] == 0 {
                scope.release_value(id);
                release_after[id].push(id);
            }
            // The in-place operand's reference was consumed by the op
            // itself; regular input releases skip it.
            let inplace_operand = match action {
                ValueAction::InPlace { pos } => Some(node.inputs[pos]),
                _ => None,
            };
            let mut decremented: Vec<NodeId> = Vec::new();
            for &i in &node.inputs {
                refcount[i] -= 1;
                if refcount[i] == 0 && !decremented.contains(&i) {
                    decremented.push(i);
                    if Some(i) == inplace_operand {
                        continue; // storage transferred, not released
                    }
                    scope.release_value(i);
                    release_after[id].push(i);
                }
            }
        }

        // Fire regions triggered at this id (mirrors execute_chunked).
        if let Some(plan_ids) = triggers.get(&id) {
            for &pi in plan_ids {
                let plan = &plans[pi];
                let mut region = plan_region_lane(graph, plan, &scope, &eff);

                // Pass-input copies (outer arena, held for the region).
                for &p in &plan.pass_inputs {
                    let v = scope.view[p].clone().expect("pass input not live");
                    let slot = if v.has_broadcast_stride() || v.is_contiguous() {
                        None
                    } else {
                        Some(scope.alloc.alloc(v.numel() * 4))
                    };
                    region.pass_slots.push(slot);
                }
                // Output accumulators (outer arena, become the outputs).
                for &(o, _) in &plan.outputs {
                    let slot = scope.alloc.alloc(graph.node(o).byte_size());
                    region.accum_slots.push(slot);
                    scope.bind_slot(o, slot, ViewState::contiguous(&graph.node(o).shape));
                    stats.materialized += 1;
                }
                if scope.alloc.trace_on {
                    scope.alloc.trace.push(PlanEvent::Probe(region.lane_admission));
                }
                admission_peak = admission_peak
                    .max(input_bytes + scope.alloc.live_sum + region.lane_admission);

                // Region end: pass copies drop.
                for slot in region.pass_slots.iter().flatten() {
                    scope.alloc.free_slot(*slot);
                }
                // External inputs consumed by the region.
                let mut decremented: Vec<NodeId> = Vec::new();
                for &r in &plan.region {
                    for &i in &graph.node(r).inputs {
                        if owner[i] != Some(pi) {
                            refcount[i] -= 1;
                            if refcount[i] == 0 && !decremented.contains(&i) {
                                decremented.push(i);
                                scope.release_value(i);
                                region.post_releases.push(i);
                            }
                        }
                    }
                }
                // Region outputs: internal consumptions already happened.
                let region_set: std::collections::HashSet<NodeId> =
                    plan.region.iter().copied().collect();
                for &(o, _) in &plan.outputs {
                    let internal_users =
                        users[o].iter().filter(|u| region_set.contains(u)).count();
                    refcount[o] -= internal_users;
                    if refcount[o] == 0 {
                        scope.release_value(o);
                        region.post_releases.push(o);
                    }
                }
                regions[pi] = Some(region);
            }
        }
        if scope.alloc.trace_on {
            pos_end[id] = scope.alloc.trace.len();
        }
    }

    let trace = std::mem::take(&mut scope.alloc.trace);
    let mut mem = MemPlan {
        actions,
        release_after,
        planned_peak_bytes: scope.alloc.peak,
        footprint_bytes: scope
            .alloc
            .slots
            .iter()
            .map(|s| s.offset + s.bytes)
            .max()
            .unwrap_or(0),
        slots: scope.alloc.slots,
        values_materialized: stats.materialized,
        inplace_count: stats.inplace,
        alias_count: stats.aliased,
        input_bytes,
        persistent_bytes,
        persistent_inputs: graph.persistent.len(),
        admission_base: admission_peak,
        spills: Vec::new(),
        spill_transfer_bytes: 0,
        spill_recompute_flops: 0,
        spill_saved_bytes: 0,
        regions: regions.into_iter().map(|r| r.expect("region planned")).collect(),
    };

    if let Some(params) = spill {
        let ctx = SpillCtx {
            trace: &trace,
            pos_end: &pos_end,
            node_transient: &node_transient,
            input_bytes,
        };
        debug_assert_eq!(
            ctx.replay(&[]),
            (mem.planned_peak_bytes, mem.admission_base),
            "event trace must reproduce legacy peak/admission exactly"
        );
        let mut trigger_pos: Vec<usize> = vec![0; plans.len()];
        for (&t, pis) in &triggers {
            for &pi in pis {
                trigger_pos[pi] = t;
            }
        }
        let accepted = choose_spills(graph, &mem, &ctx, &users, &owner, &trigger_pos, params.gbps);
        if !accepted.is_empty() {
            let (peak, admission) = ctx.replay(&accepted);
            mem.spill_saved_bytes = mem.planned_peak_bytes - peak;
            mem.planned_peak_bytes = peak;
            mem.admission_base = admission;
            mem.spill_transfer_bytes = accepted
                .iter()
                .filter(|d| d.kind == SpillKind::Offload)
                .map(|d| 2 * d.bytes)
                .sum();
            mem.spill_recompute_flops = accepted
                .iter()
                .filter(|d| d.kind == SpillKind::Recompute)
                .map(|d| crate::ir::flops::node_flops(graph, d.value) as usize)
                .sum();
            mem.spills = accepted;
        }
    }
    mem
}

// ------------------------------------------------------ placement search

/// Replay context: the recorded event log plus the per-position
/// watermarks needed to splice spill decisions into it.
struct SpillCtx<'a> {
    trace: &'a [PlanEvent],
    pos_end: &'a [usize],
    node_transient: &'a [usize],
    input_bytes: usize,
}

impl SpillCtx<'_> {
    /// Replay the event log with `decisions` spliced in, returning the
    /// exact `(planned_peak_bytes, admission_base)` of the resulting
    /// plan. Within a position the order is: restores first, then the
    /// position's recorded events, then spills — the same order the
    /// arena executor runs the script, so runtime high-water stays equal
    /// to the replayed peak.
    fn replay(&self, decisions: &[SpillDecision]) -> (usize, usize) {
        let n = self.pos_end.len();
        let mut restore_at: HashMap<usize, Vec<usize>> = HashMap::new();
        let mut spill_at: HashMap<usize, Vec<usize>> = HashMap::new();
        for (di, d) in decisions.iter().enumerate() {
            restore_at.entry(d.restore_before).or_default().push(di);
            spill_at.entry(d.spill_after).or_default().push(di);
        }
        let mut live = 0usize;
        let mut peak = 0usize;
        let mut admission = self.input_bytes;
        let mut cursor = 0usize;
        for p in 0..n {
            if let Some(dis) = restore_at.get(&p) {
                for &di in dis {
                    let d = &decisions[di];
                    live += d.bytes;
                    peak = peak.max(live);
                    if d.kind == SpillKind::Recompute {
                        admission = admission
                            .max(self.input_bytes + live + self.node_transient[d.value]);
                    }
                }
            }
            while cursor < self.pos_end[p] {
                match self.trace[cursor] {
                    PlanEvent::Alloc(b) => {
                        live += b;
                        peak = peak.max(live);
                    }
                    PlanEvent::Free(b) => {
                        debug_assert!(live >= b, "replay free underflow");
                        live -= b;
                    }
                    PlanEvent::Probe(extra) => {
                        admission = admission.max(self.input_bytes + live + extra);
                    }
                }
                cursor += 1;
            }
            if let Some(dis) = spill_at.get(&p) {
                for &di in dis {
                    let d = &decisions[di];
                    debug_assert!(live >= d.bytes, "replay spill underflow");
                    live -= d.bytes;
                }
            }
        }
        (peak, admission)
    }
}

/// True when accepting `cand` would break a recompute decision's live
/// frontier (or `cand` itself recomputes from a value another accepted
/// decision has spilled out across `cand`'s restore point). Restores at
/// the same position deliberately don't chain.
fn recompute_conflict(graph: &Graph, accepted: &[SpillDecision], cand: &SpillDecision) -> bool {
    if cand.kind == SpillKind::Recompute {
        for &i in &graph.node(cand.value).inputs {
            if accepted.iter().any(|d| {
                d.value == i && d.spill_after < cand.restore_before
                    && d.restore_before >= cand.restore_before
            }) {
                return true;
            }
        }
    }
    accepted.iter().any(|d| {
        d.kind == SpillKind::Recompute
            && graph.node(d.value).inputs.contains(&cand.value)
            && cand.spill_after < d.restore_before
            && cand.restore_before >= d.restore_before
    })
}

/// Enumerate spillable (value, gap) candidates and accept them greedily,
/// largest planned bytes first, while the replayed peak/admission pair
/// strictly improves and never regresses. Deterministic: ties break on
/// modeled cost, then (value, spill_after).
fn choose_spills(
    graph: &Graph,
    mem: &MemPlan,
    ctx: &SpillCtx,
    users: &[Vec<NodeId>],
    owner: &[Option<usize>],
    trigger_pos: &[usize],
    gbps: f64,
) -> Vec<SpillDecision> {
    use crate::ir::flops::node_flops;
    use crate::passes::select::placement_cost_us;

    let n = graph.len();
    // Values whose storage root is shared by a zero-copy alias can't
    // free arena bytes by dropping, and in-place consumers empty their
    // operand without a release event — both disqualify.
    let mut has_alias_user = vec![false; n];
    let mut inplace_consumed = vec![false; n];
    for node in &graph.nodes {
        match mem.actions[node.id] {
            ValueAction::Alias => has_alias_user[node.inputs[0]] = true,
            ValueAction::InPlace { pos } => inplace_consumed[node.inputs[pos]] = true,
            _ => {}
        }
    }
    // Position at which each value's release event fires (usize::MAX =
    // never released: outputs and caller-held inputs).
    let mut release_pos: Vec<usize> = vec![usize::MAX; n];
    for (p, rel) in mem.release_after.iter().enumerate() {
        for &i in rel {
            release_pos[i] = p;
        }
    }
    for (pi, region) in mem.regions.iter().enumerate() {
        for &i in &region.post_releases {
            release_pos[i] = trigger_pos[pi];
        }
    }

    let mut cands: Vec<SpillDecision> = Vec::new();
    for v in 0..n {
        let ValueAction::Materialize { slot } = mem.actions[v] else {
            continue;
        };
        let node = graph.node(v);
        if node.dtype != DType::F32 {
            continue;
        }
        // Broadcast materializes a smaller buffer behind a stride-0 view;
        // Opaque the executor refuses to run (and to re-run).
        if matches!(node.op, Op::Broadcast { .. } | Op::Opaque { .. }) {
            continue;
        }
        if has_alias_user[v] {
            continue;
        }
        // Use positions: direct consumers at their own ids, region-owned
        // consumers at their region's trigger.
        let mut use_pos: Vec<usize> = users[v]
            .iter()
            .map(|&u| match owner[u] {
                Some(pi) => trigger_pos[pi],
                None => u,
            })
            .collect();
        use_pos.sort_unstable();
        use_pos.dedup();
        if use_pos.is_empty() {
            continue;
        }
        let bytes = mem.slots[slot].bytes;
        // Recompute needs every input still live (not released, not
        // in-place-consumed) at the restore point.
        let recompute_ok = |b: usize| {
            node.inputs
                .iter()
                .all(|&i| release_pos[i] >= b && !inplace_consumed[i])
        };
        let mut positions = vec![v];
        positions.extend(use_pos);
        for w in positions.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b <= a + 1 {
                continue; // adjacent positions: nothing lives in between
            }
            let offload_cost = placement_cost_us(2 * bytes, 0, gbps);
            let (kind, cost_us) = if recompute_ok(b) {
                let rc = placement_cost_us(0, node_flops(graph, v) as usize, gbps);
                if rc <= offload_cost {
                    (SpillKind::Recompute, rc)
                } else {
                    (SpillKind::Offload, offload_cost)
                }
            } else {
                (SpillKind::Offload, offload_cost)
            };
            cands.push(SpillDecision {
                value: v,
                slot,
                bytes,
                spill_after: a,
                restore_before: b,
                kind,
                cost_us,
            });
        }
    }

    cands.sort_by(|x, y| {
        y.bytes
            .cmp(&x.bytes)
            .then(x.cost_us.partial_cmp(&y.cost_us).unwrap_or(std::cmp::Ordering::Equal))
            .then(x.value.cmp(&y.value))
            .then(x.spill_after.cmp(&y.spill_after))
    });
    cands.truncate(64);

    let mut accepted: Vec<SpillDecision> = Vec::new();
    let (mut cur_peak, mut cur_admission) = ctx.replay(&accepted);
    for c in cands {
        if recompute_conflict(graph, &accepted, &c) {
            continue;
        }
        accepted.push(c);
        let (peak, admission) = ctx.replay(&accepted);
        let improves = peak <= cur_peak
            && admission <= cur_admission
            && (peak < cur_peak || admission < cur_admission);
        if improves {
            cur_peak = peak;
            cur_admission = admission;
        } else {
            accepted.pop();
        }
    }
    accepted.sort_by_key(|d| (d.spill_after, d.value, d.restore_before));
    accepted
}

/// Plan one region body at the full chunk step: lane slots, actions,
/// release script, and the exact lane peak. `outer` provides the view
/// states of the region's external inputs (chunk inputs are sliced from
/// them, pass inputs bound as the runtime binds them).
fn plan_region_lane(
    graph: &Graph,
    plan: &ChunkPlan,
    outer: &Scope,
    outer_eff: &EffShapes,
) -> RegionMemPlan {
    let step = plan.chunk_step(graph);
    let region_set: std::collections::HashSet<NodeId> = plan.region.iter().copied().collect();

    // Lane-internal refcounts: uses by region nodes; outputs pinned until
    // the accumulator push at iteration end.
    let mut refcount: Vec<usize> = vec![0; graph.len()];
    for &r in &plan.region {
        for &i in &graph.node(r).inputs {
            refcount[i] += 1;
        }
    }
    for &(o, _) in &plan.outputs {
        refcount[o] += 1;
    }

    // Effective shapes: region nodes (and chunk inputs) scale their chunk
    // dim to the step.
    let mut eff: EffShapes = outer_eff.clone();
    for &r in &plan.region {
        let dim = plan.node_dims[&r];
        let mut s = graph.node(r).shape.clone();
        s[dim] = step.min(s[dim]);
        eff[r] = s;
    }
    for &(i, axis) in &plan.chunk_inputs {
        let mut s = graph.node(i).shape.clone();
        s[axis] = step.min(s[axis]);
        eff[i] = s;
    }

    let mut scope = Scope::new(graph.len());
    let mut stats = PlanStats::default();

    // Bind externals with the runtime's exact view states.
    for &(i, axis) in &plan.chunk_inputs {
        let base = outer.view[i].clone().expect("chunk input not live");
        let v = base.slice_axis(axis, 0, eff[i][axis]);
        scope.bind_external(i, v);
    }
    for &p in &plan.pass_inputs {
        let base = outer.view[p].clone().expect("pass input not live");
        let v = if base.has_broadcast_stride() || base.is_contiguous() {
            base // passed as-is (clone / to_contiguous no-op)
        } else {
            ViewState::contiguous(&outer_eff[p]) // materialized pass copy
        };
        scope.bind_external(p, v);
    }

    let mut actions: Vec<(NodeId, ValueAction)> = Vec::with_capacity(plan.region.len());
    let mut release_after: Vec<Vec<NodeId>> = Vec::with_capacity(plan.region.len());
    let mut lane_admission = 0usize;

    for &r in &plan.region {
        let node = graph.node(r);
        let (action, transient) =
            process_node(graph, node, &eff, &mut scope, &refcount, &mut stats);
        lane_admission = lane_admission.max(scope.alloc.live_sum + transient);
        let mut releases: Vec<NodeId> = Vec::new();
        if refcount[r] == 0 {
            scope.release_value(r);
            releases.push(r);
        }
        let inplace_operand = match action {
            ValueAction::InPlace { pos } => Some(node.inputs[pos]),
            _ => None,
        };
        let mut decremented: Vec<NodeId> = Vec::new();
        for &i in &node.inputs {
            refcount[i] -= 1;
            if refcount[i] == 0 && !decremented.contains(&i) && region_set.contains(&i) {
                decremented.push(i);
                if Some(i) == inplace_operand {
                    continue;
                }
                scope.release_value(i);
                releases.push(i);
            }
        }
        actions.push((r, action));
        release_after.push(releases);
    }
    // Accumulator pushes materialize non-contiguous output chunks
    // transiently (tracked) before their copy; charge the worst case on
    // top of the end-of-iteration live set.
    let push_ws: usize = plan
        .outputs
        .iter()
        .filter_map(|&(o, _)| scope.view[o].as_ref())
        .filter(|v| !v.is_contiguous())
        .map(|v| v.numel() * 4)
        .sum();
    lane_admission = lane_admission.max(scope.alloc.live_sum + push_ws);
    lane_admission = lane_admission.max(scope.alloc.peak);

    RegionMemPlan {
        actions,
        release_after,
        lane_bytes: scope.alloc.peak,
        lane_admission,
        slots: scope.alloc.slots,
        accum_slots: Vec::new(),
        pass_slots: Vec::new(),
        post_releases: Vec::new(),
    }
}

/// Stable, human-readable rendering of a memory plan — the golden
/// memory-profile snapshot format (`rust/tests/memplan_golden.rs`). All
/// integer arithmetic, so the fixture is bitwise stable.
pub fn describe_memplan(plan: &MemPlan) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "planned_peak_bytes: {}", plan.planned_peak_bytes);
    let _ = writeln!(s, "footprint_bytes: {}", plan.footprint_bytes);
    let _ = writeln!(s, "slots: {}", plan.slots.len());
    let _ = writeln!(s, "values_materialized: {}", plan.values_materialized);
    let _ = writeln!(s, "aliases: {}", plan.alias_count);
    let _ = writeln!(s, "inplace: {}", plan.inplace_count);
    // reuse ratio ×100, integer-rounded, for float-free fixtures
    let _ = writeln!(
        s,
        "reuse_ratio_pct: {}",
        plan.values_materialized * 100 / plan.slots.len().max(1)
    );
    let _ = writeln!(s, "admission_base: {}", plan.admission_base);
    let _ = writeln!(s, "persistent_bytes: {}", plan.persistent_bytes);
    let _ = writeln!(s, "persistent_inputs: {}", plan.persistent_inputs);
    let _ = writeln!(s, "regions: {}", plan.regions.len());
    for (i, r) in plan.regions.iter().enumerate() {
        let _ = writeln!(
            s,
            "region {i}: lane_bytes={} lane_admission={} slots={} accums={}",
            r.lane_bytes,
            r.lane_admission,
            r.slots.len(),
            r.accum_slots.len()
        );
    }
    // Spill-tier line only when decisions exist, so default (spill-off)
    // fixtures stay bitwise identical to the legacy format.
    if !plan.spills.is_empty() {
        let offloads = plan
            .spills
            .iter()
            .filter(|d| d.kind == SpillKind::Offload)
            .count();
        let _ = writeln!(
            s,
            "spills: {} offloads={} recomputes={} transfer_bytes={} recompute_flops={} saved_bytes={}",
            plan.spills.len(),
            offloads,
            plan.spills.len() - offloads,
            plan.spill_transfer_bytes,
            plan.spill_recompute_flops,
            plan.spill_saved_bytes
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::tensor::ops::{BinaryOp, UnaryOp};

    #[test]
    fn allocator_best_fit_reuses_gaps() {
        let mut a = Allocator::default();
        let s1 = a.alloc(100);
        let s2 = a.alloc(50);
        assert_eq!(a.slots[s1].offset, 0);
        assert_eq!(a.slots[s2].offset, 100);
        a.free_slot(s1);
        // 40 fits the 100-gap (best fit), not the arena end
        let s3 = a.alloc(40);
        assert_eq!(a.slots[s3].offset, 0);
        // 60 fits the remaining 60-byte tail of the gap
        let s4 = a.alloc(60);
        assert_eq!(a.slots[s4].offset, 40);
        assert_eq!(a.end, 150, "no growth needed");
        assert_eq!(a.peak, 150);
        a.free_slot(s2);
        a.free_slot(s3);
        a.free_slot(s4);
        assert_eq!(a.live_sum, 0);
        // full merge back to one gap
        assert_eq!(a.free, vec![(0, 150)]);
    }

    #[test]
    fn allocator_same_interval_reuses_slot_id() {
        let mut a = Allocator::default();
        let s1 = a.alloc(64);
        a.free_slot(s1);
        let s2 = a.alloc(64);
        assert_eq!(s1, s2, "vacated interval reuses its slot id");
        assert_eq!(a.slots.len(), 1);
    }

    #[test]
    fn chain_reuses_slots_via_inplace() {
        // x -> relu -> gelu -> tanh: the elementwise chain computes in
        // place, so exactly one slot exists.
        let mut b = GraphBuilder::new("chain");
        let x = b.input("x", &[64]);
        let a1 = b.unary(UnaryOp::Relu, x);
        let a2 = b.unary(UnaryOp::Gelu, a1);
        let a3 = b.unary(UnaryOp::Tanh, a2);
        let g = b.finish(vec![a3]);
        let plan = plan_memory(&g, &[]);
        // a1 materializes (input is external); a2, a3 run in place
        assert_eq!(plan.slots.len(), 1, "{:?}", plan.slots);
        assert_eq!(plan.inplace_count, 2);
        assert_eq!(plan.planned_peak_bytes, 64 * 4);
        assert_eq!(plan.actions[a2], ValueAction::InPlace { pos: 0 });
        assert_eq!(plan.actions[a3], ValueAction::InPlace { pos: 0 });
    }

    #[test]
    fn use_twice_rejects_inplace() {
        // c = a * a with a still needed by d: neither use may clobber a.
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[8]);
        let a = b.unary(UnaryOp::Relu, x);
        let c = b.binary(BinaryOp::Mul, a, a);
        let d = b.binary(BinaryOp::Add, c, a);
        let g = b.finish(vec![d]);
        let plan = plan_memory(&g, &[]);
        // at c, a has 3 outstanding uses (2 here + 1 at d) -> materialize
        assert!(
            matches!(plan.actions[c], ValueAction::Materialize { .. }),
            "{:?}",
            plan.actions[c]
        );
        // at d, c dies (multiplicity 1, refcount 1) -> in place into c
        assert_eq!(plan.actions[d], ValueAction::InPlace { pos: 0 });
    }

    #[test]
    fn live_alias_rejects_inplace() {
        // A transpose view of `a` is still live when relu(a) runs: the
        // planner must copy, not write through the alias.
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 4]);
        let a = b.unary(UnaryOp::Relu, x); // slot-backed
        let t = b.transpose(a, &[1, 0]); // live alias of a
        let u = b.unary(UnaryOp::Neg, a); // a's last direct use
        let s = b.binary(BinaryOp::Add, t, u);
        let g = b.finish(vec![s]);
        let plan = plan_memory(&g, &[]);
        assert!(
            matches!(plan.actions[u], ValueAction::Materialize { .. }),
            "in-place through a live alias is the use-twice hazard: {:?}",
            plan.actions[u]
        );
    }

    #[test]
    fn external_operands_never_inplace() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[16]);
        let y = b.unary(UnaryOp::Relu, x); // x is caller-owned
        let g = b.finish(vec![y]);
        let plan = plan_memory(&g, &[]);
        assert!(matches!(plan.actions[y], ValueAction::Materialize { .. }));
    }

    #[test]
    fn views_alias_and_allocate_nothing() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[8, 8]);
        let t = b.transpose(x, &[1, 0]);
        let s = b.slice(t, 0, 0, 4);
        let g = b.finish(vec![s]);
        let plan = plan_memory(&g, &[]);
        assert_eq!(plan.actions[t], ValueAction::Alias);
        assert_eq!(plan.actions[s], ValueAction::Alias);
        assert_eq!(plan.planned_peak_bytes, 0, "views of inputs cost nothing");
        assert_eq!(plan.alias_count, 2);
    }

    #[test]
    fn liveness_chain_peak_is_two_values() {
        // matmul chain: cur and next overlap transiently; peak = 2 slots.
        let mut b = GraphBuilder::new("chain");
        let x = b.input("x", &[32, 32]);
        let w = b.param("w", &[32, 32]);
        let mut cur = x;
        for _ in 0..6 {
            cur = b.matmul(cur, w);
        }
        let g = b.finish(vec![cur]);
        let plan = plan_memory(&g, &[]);
        assert_eq!(plan.planned_peak_bytes, 2 * 32 * 32 * 4);
        assert!(plan.slots.len() <= 2, "{} slots", plan.slots.len());
        assert!(plan.reuse_ratio() >= 2.9, "{}", plan.reuse_ratio());
    }

    #[test]
    fn planner_is_deterministic() {
        let g = crate::models::gpt(&crate::models::GptConfig {
            seq: 64,
            layers: 1,
            ..Default::default()
        });
        let a = describe_memplan(&plan_memory(&g, &[]));
        let b = describe_memplan(&plan_memory(&g, &[]));
        assert_eq!(a, b);
    }

    #[test]
    fn reuse_ratio_finite_on_empty_plan() {
        // A pure-view graph materializes nothing: zero slots must give a
        // finite 0.0 ratio, never NaN (satellite: zero-denominator audit).
        let mut b = GraphBuilder::new("views");
        let x = b.input("x", &[8, 8]);
        let t = b.transpose(x, &[1, 0]);
        let g = b.finish(vec![t]);
        let plan = plan_memory(&g, &[]);
        assert_eq!(plan.slots.len(), 0);
        assert!(plan.reuse_ratio().is_finite());
        assert_eq!(plan.reuse_ratio(), 0.0);
    }

    /// Chain with a long-range residual: `a` is live across the whole
    /// chain, so a spill window exists between its two uses.
    fn residual_chain() -> crate::ir::Graph {
        let mut b = GraphBuilder::new("residual");
        let x = b.input("x", &[64, 64]);
        let w = b.param("w", &[64, 64]);
        let a = b.matmul(x, w);
        let mut cur = a;
        for _ in 0..4 {
            cur = b.matmul(cur, w);
        }
        let out = b.binary(BinaryOp::Add, cur, a);
        b.finish(vec![out])
    }

    #[test]
    fn spill_disabled_matches_legacy_bitwise() {
        let g = residual_chain();
        let off = plan_memory_with(&g, &[], None);
        assert!(off.spills.is_empty());
        assert_eq!(off.spill_transfer_bytes, 0);
        assert_eq!(off.spill_saved_bytes, 0);
        // env default (unset in tests) must be the same plan
        let env = plan_memory(&g, &[]);
        assert_eq!(describe_memplan(&off), describe_memplan(&env));
    }

    #[test]
    fn spill_reduces_peak_and_admission_on_residual_gap() {
        let g = residual_chain();
        let off = plan_memory_with(&g, &[], None);
        let on = plan_memory_with(&g, &[], Some(SpillParams { gbps: 16.0 }));
        assert!(!on.spills.is_empty(), "residual gap must yield a spill");
        assert!(
            on.planned_peak_bytes < off.planned_peak_bytes,
            "spill {} !< legacy {}",
            on.planned_peak_bytes,
            off.planned_peak_bytes
        );
        assert!(on.admission_base <= off.admission_base);
        assert_eq!(
            on.spill_saved_bytes,
            off.planned_peak_bytes - on.planned_peak_bytes
        );
        // offsets/slots untouched: placement never re-layouts the arena
        assert_eq!(on.footprint_bytes, off.footprint_bytes);
        assert_eq!(on.slots.len(), off.slots.len());
        for d in &on.spills {
            assert!(d.restore_before > d.spill_after + 1);
            assert_eq!(d.bytes, on.slots[d.slot].bytes);
            assert!(d.cost_us >= 0.0 && d.cost_us.is_finite());
        }
    }

    #[test]
    fn spill_search_is_deterministic() {
        let g = crate::models::gpt(&crate::models::GptConfig {
            seq: 64,
            layers: 2,
            ..Default::default()
        });
        let p = Some(SpillParams { gbps: 8.0 });
        let a = describe_memplan(&plan_memory_with(&g, &[], p));
        let b = describe_memplan(&plan_memory_with(&g, &[], p));
        assert_eq!(a, b);
    }
}
