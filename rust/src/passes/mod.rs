//! AutoChunk compiler passes.
//!
//! Pipeline (paper §3.2, Figure 3): for a given memory budget,
//!
//! 1. [`estimate`] — activation-memory profile + peak node;
//! 2. [`search`] — enumerate legal chunk candidates around the peak
//!    (Algorithm 1, bottom-up BFS over chunk flows);
//! 3. [`select`] — score candidates with the macro/micro cost functions
//!    (Eq. 8–10) and pick the best via DP + beam search;
//! 4. repeat until the estimated peak fits the budget.
//!
//! [`autochunk`] is the user-facing wrapper, mirroring the paper's
//! `model = autochunk(model, memory_budget)`.

pub mod estimate;
pub mod expert;
pub mod flow;
pub mod memplan;
pub mod search;
pub mod select;

pub use estimate::{
    cost_quote, estimate, estimate_under_plan, peak_upper_bound, planner_gap, CostQuote,
    MemoryProfile, PlannerGap,
};
pub use memplan::{
    describe_memplan, plan_memory, plan_memory_with, spill_params_from_env, MemPlan,
    RegionMemPlan, SpillDecision, SpillKind, SpillParams, ValueAction,
};
pub use search::{search_chunks, ChunkCandidate, SearchConfig};
pub use select::{select_chunks, SelectConfig};

use crate::ir::Graph;
use crate::plan::ChunkPlan;

/// Outcome of the full AutoChunk compilation.
#[derive(Clone, Debug)]
pub struct AutoChunkResult {
    /// Chosen chunk plans, in application order.
    pub plans: Vec<ChunkPlan>,
    /// Estimated peak activation bytes before chunking.
    pub baseline_peak: usize,
    /// Estimated peak activation bytes under `plans`.
    pub chunked_peak: usize,
    /// Total selection cost (Σ L(sᵢ), Eq. 11) of the chosen plans.
    pub total_cost: f64,
    /// Chunk candidates enumerated across all search passes — recorded
    /// in compile trace spans so a trace explains how wide the search
    /// actually ran (DESIGN.md §19).
    pub candidates_seen: usize,
}

/// Options for the full pipeline.
#[derive(Clone, Debug)]
pub struct AutoChunkConfig {
    pub search: SearchConfig,
    pub select: SelectConfig,
    /// Upper bound on search/select iterations (passes over the graph).
    pub max_passes: usize,
    /// Beam width of the DP-over-passes (1 = greedy).
    pub beam_width: usize,
}

impl Default for AutoChunkConfig {
    fn default() -> Self {
        AutoChunkConfig {
            search: SearchConfig::default(),
            select: SelectConfig::default(),
            max_passes: 64,
            beam_width: 3,
        }
    }
}

/// One partial strategy in the DP/beam frontier.
#[derive(Clone, Debug)]
struct BeamState {
    plans: Vec<ChunkPlan>,
    cost: f64,
    peak: usize,
}

/// The paper's `autochunk(model, memory_budget)` (Eq. 11): search for the
/// chunk strategy `S = [s₁..s_l]` minimizing `Σ L(sᵢ)` subject to
/// `peak < budget`, via dynamic programming over passes with beam search.
/// Each pass re-estimates memory under the partial strategy (chunk
/// inter-dependency handling, §3.4) and attacks the remaining peak.
pub fn autochunk(graph: &Graph, budget_bytes: usize, config: &AutoChunkConfig) -> AutoChunkResult {
    let baseline = estimate(graph);
    let mut beam = vec![BeamState {
        plans: Vec::new(),
        cost: 0.0,
        peak: baseline.peak_bytes,
    }];
    let mut best_complete: Option<BeamState> = None;
    let mut best_partial: BeamState = beam[0].clone();
    let mut candidates_seen = 0usize;

    for _pass in 0..config.max_passes {
        let mut frontier: Vec<BeamState> = Vec::new();
        for state in &beam {
            if state.peak <= budget_bytes {
                // complete: candidate answer, do not expand
                let better = best_complete
                    .as_ref()
                    .map(|b| state.cost < b.cost)
                    .unwrap_or(true);
                if better {
                    best_complete = Some(state.clone());
                }
                continue;
            }
            if state.peak < best_partial.peak {
                best_partial = state.clone();
            }
            let profile = estimate_under_plan(graph, &state.plans);
            let candidates = search_chunks(graph, &profile, &state.plans, &config.search);
            candidates_seen += candidates.len();
            let ranked = select::rank_candidates(
                graph,
                &candidates,
                &state.plans,
                budget_bytes,
                &config.select,
            );
            for sc in ranked.into_iter().take(config.beam_width) {
                let mut plans = state.plans.clone();
                plans.push(sc.plan);
                let peak = estimate_under_plan(graph, &plans).peak_bytes;
                frontier.push(BeamState {
                    plans,
                    cost: state.cost + sc.cost,
                    peak,
                });
            }
        }
        if frontier.is_empty() {
            break;
        }
        // Keep the lowest-cost `beam_width` states (DP prune).
        frontier.sort_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap());
        frontier.truncate(config.beam_width);
        beam = frontier;
    }
    // Any still-live complete states in the final beam.
    for state in &beam {
        if state.peak <= budget_bytes {
            let better = best_complete
                .as_ref()
                .map(|b| state.cost < b.cost)
                .unwrap_or(true);
            if better {
                best_complete = Some(state.clone());
            }
        } else if state.peak < best_partial.peak {
            best_partial = state.clone();
        }
    }

    let mut chosen = best_complete.unwrap_or(best_partial);

    // Deepening post-pass: if the budget is still unmet and the residual
    // peak sits inside one of our regions, double that plan's chunk count
    // (chunk counts were kept shallow while other regions gated the peak).
    let mut stagnant = 0usize;
    for _ in 0..64 {
        if chosen.peak <= budget_bytes || stagnant > chosen.plans.len() {
            break;
        }
        let profile = estimate_under_plan(graph, &chosen.plans);
        // Match by region *span*: the peak moment may land on a node the
        // region excludes (a const-derived view) while the surrounding
        // plan still governs the live set.
        let Some(pi) = chosen.plans.iter().position(|p| {
            p.contains(profile.peak_node)
                || (*p.region.first().unwrap() <= profile.peak_node
                    && profile.peak_node <= *p.region.last().unwrap())
        }) else {
            break;
        };
        let extent = chosen.plans[pi].chunk_extent(graph);
        if chosen.plans[pi].n_chunks >= extent.min(config.select.max_chunks) {
            break;
        }
        let old_n = chosen.plans[pi].n_chunks;
        chosen.plans[pi].n_chunks = (old_n * 2).min(extent);
        let after = estimate_under_plan(graph, &chosen.plans);
        if after.peak_bytes > chosen.peak {
            chosen.plans[pi].n_chunks = old_n; // revert
            break;
        }
        // equal peak but moved to another region: keep going (stacked
        // identical layers gate each other one at a time)
        stagnant = if after.peak_bytes == chosen.peak {
            if after.peak_node == profile.peak_node {
                chosen.plans[pi].n_chunks = old_n;
                break;
            }
            stagnant + 1
        } else {
            0
        };
        chosen.peak = after.peak_bytes;
    }

    AutoChunkResult {
        plans: chosen.plans,
        baseline_peak: baseline.peak_bytes,
        chunked_peak: chosen.peak,
        total_cost: chosen.cost,
        candidates_seen,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute, random_inputs, random_params};
    use crate::ir::GraphBuilder;
    use crate::plan::execute_chunked;
    use crate::tensor::ops::{BinaryOp, UnaryOp};
    use crate::tensor::MemoryTracker;

    fn transformer_block(s: usize, d: usize) -> crate::ir::Graph {
        let mut b = GraphBuilder::new("block");
        let x = b.input("x", &[s, d]);
        let wq = b.param("wq", &[d, d]);
        let wk = b.param("wk", &[d, d]);
        let wv = b.param("wv", &[d, d]);
        let q = b.matmul(x, wq);
        let k = b.matmul(x, wk);
        let v = b.matmul(x, wv);
        let kt = b.transpose(k, &[1, 0]);
        let scores = b.matmul(q, kt);
        let scaled = b.binary_scalar(BinaryOp::Mul, scores, 0.125);
        let probs = b.softmax(scaled, 1);
        let attn = b.matmul(probs, v);
        let res = b.add(attn, x);
        let w1 = b.param("w1", &[d, 4 * d]);
        let h = b.matmul(res, w1);
        let a = b.unary(UnaryOp::Gelu, h);
        let w2 = b.param("w2", &[4 * d, d]);
        let ff = b.matmul(a, w2);
        let y = b.add(ff, res);
        b.finish(vec![y])
    }

    #[test]
    fn autochunk_meets_half_budget() {
        let g = transformer_block(512, 32);
        let base = estimate(&g).peak_bytes;
        let result = autochunk(&g, base / 2, &AutoChunkConfig::default());
        assert!(!result.plans.is_empty());
        assert!(
            result.chunked_peak <= base / 2,
            "peak {} budget {}",
            result.chunked_peak,
            base / 2
        );
    }

    #[test]
    fn autochunk_meets_fifth_budget() {
        let g = transformer_block(512, 32);
        let base = estimate(&g).peak_bytes;
        let result = autochunk(&g, base / 5, &AutoChunkConfig::default());
        assert!(
            result.chunked_peak <= base * 30 / 100,
            "peak {} vs base {}",
            result.chunked_peak,
            base
        );
    }

    #[test]
    fn autochunk_plans_execute_correctly() {
        let g = transformer_block(128, 16);
        let base = estimate(&g).peak_bytes;
        let result = autochunk(&g, base / 3, &AutoChunkConfig::default());
        assert!(!result.plans.is_empty());
        let ins = random_inputs(&g, 77, None);
        let ps = random_params(&g, 78);
        let t0 = MemoryTracker::new();
        let (want, _) = execute(&g, &ins, &ps, &t0);
        let t1 = MemoryTracker::new();
        let (got, _) = execute_chunked(&g, &result.plans, &ins, &ps, &t1);
        assert!(want[0].max_abs_diff(&got[0]) < 1e-4);
    }

    #[test]
    fn measured_peak_tracks_estimate() {
        let g = transformer_block(256, 16);
        let base_prof = estimate(&g);
        let result = autochunk(&g, base_prof.peak_bytes / 3, &AutoChunkConfig::default());
        let tracker = MemoryTracker::new();
        let ins: Vec<_> = random_inputs(&g, 1, Some(tracker.clone()));
        let ps = random_params(&g, 2);
        let (_, stats) = execute_chunked(&g, &result.plans, &ins, &ps, &tracker);
        let ratio = stats.peak_bytes as f64 / result.chunked_peak as f64;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "measured {} vs estimated {} (ratio {ratio:.2})",
            stats.peak_bytes,
            result.chunked_peak
        );
    }

    #[test]
    fn beam_not_worse_than_greedy() {
        let g = transformer_block(512, 32);
        let base = estimate(&g).peak_bytes;
        let greedy = autochunk(
            &g,
            base / 4,
            &AutoChunkConfig {
                beam_width: 1,
                ..Default::default()
            },
        );
        let beam = autochunk(
            &g,
            base / 4,
            &AutoChunkConfig {
                beam_width: 4,
                ..Default::default()
            },
        );
        if greedy.chunked_peak <= base / 4 && beam.chunked_peak <= base / 4 {
            assert!(beam.total_cost <= greedy.total_cost + 1e-9);
        }
    }

    #[test]
    fn impossible_budget_returns_best_effort() {
        let g = transformer_block(64, 16);
        let result = autochunk(&g, 1, &AutoChunkConfig::default());
        // cannot fit 1 byte, but must have tried and reduced
        let base = estimate(&g).peak_bytes;
        assert!(result.chunked_peak <= base);
    }
}
