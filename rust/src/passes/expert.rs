//! Expert-designed chunk baseline (paper §4.1, Figures 7–8).
//!
//! Reimplements the OpenFold-style hand-written chunk strategy the paper
//! compares against: every attention / transition module is chunked along
//! its leading output dimension with one *fixed* chunk size (the paper uses
//! 64 as "an effective configuration"), regardless of where the actual
//! memory peak is and with no cost model. The gap between this and
//! AutoChunk is the paper's headline comparison.

use super::search::plan_for_range;
use super::SearchConfig;
use crate::ir::{Graph, NodeId, Op};
use crate::plan::{plans_overlap, ChunkPlan};

/// Build fixed-size expert plans: for every softmax (attention core) and
/// every GELU (transition/FFN core), chunk the surrounding module region
/// along output dim 0 with `ceil(extent / chunk_size)` chunks.
pub fn expert_plans(graph: &Graph, chunk_size: usize) -> Vec<ChunkPlan> {
    let mut plans: Vec<ChunkPlan> = Vec::new();
    let anchors: Vec<NodeId> = graph
        .nodes
        .iter()
        .filter(|n| {
            matches!(n.op, Op::Softmax { .. })
                || matches!(n.op, Op::Unary(crate::tensor::ops::UnaryOp::Gelu))
        })
        .map(|n| n.id)
        .collect();

    let cfg = SearchConfig::default();
    for anchor in anchors {
        // module region: a fixed ±4-node neighborhood around the anchor —
        // the "whole module" granularity of hand-written chunk wrappers.
        let start = anchor.saturating_sub(4);
        let end = (anchor + 4).min(graph.len() - 1);
        let Some(mut plan) = widest_legal_plan(graph, start, end, anchor, &cfg) else {
            continue;
        };
        let extent = plan.chunk_extent(graph);
        if extent <= chunk_size {
            continue; // module too small to chunk at this fixed size
        }
        plan.n_chunks = extent.div_ceil(chunk_size);
        if plans.iter().any(|p| plans_overlap(p, &plan)) {
            continue;
        }
        plans.push(plan);
    }
    plans
}

/// The widest region within [start, end] containing `anchor` that admits a
/// dim-0 chunk (experts chunk whole modules along the leading dim).
fn widest_legal_plan(
    graph: &Graph,
    start: NodeId,
    end: NodeId,
    anchor: NodeId,
    cfg: &SearchConfig,
) -> Option<ChunkPlan> {
    let mut best: Option<ChunkPlan> = None;
    for s in start..=anchor {
        for e in anchor..=end {
            if let Some(plan) = plan_for_range(graph, s, e, 0, cfg) {
                if !plan.region.contains(&anchor) {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(b) => plan.region.len() > b.region.len(),
                };
                if better {
                    best = Some(plan);
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute, random_inputs, random_params};
    use crate::ir::GraphBuilder;
    use crate::plan::execute_chunked;
    use crate::tensor::ops::{BinaryOp, UnaryOp};
    use crate::tensor::MemoryTracker;

    fn block(s: usize, d: usize) -> crate::ir::Graph {
        let mut b = GraphBuilder::new("block");
        let x = b.input("x", &[s, d]);
        let wq = b.param("wq", &[d, d]);
        let q = b.matmul(x, wq);
        let kt = b.transpose(q, &[1, 0]);
        let scores = b.matmul(q, kt);
        let scaled = b.binary_scalar(BinaryOp::Mul, scores, 0.125);
        let probs = b.softmax(scaled, 1);
        let attn = b.matmul(probs, q);
        let w1 = b.param("w1", &[d, 4 * d]);
        let h = b.matmul(attn, w1);
        let a = b.unary(UnaryOp::Gelu, h);
        let w2 = b.param("w2", &[4 * d, d]);
        let y = b.matmul(a, w2);
        b.finish(vec![y])
    }

    #[test]
    fn expert_plans_found_and_disjoint() {
        let g = block(256, 16);
        let plans = expert_plans(&g, 64);
        assert!(!plans.is_empty(), "expert found no chunk modules");
        for (i, a) in plans.iter().enumerate() {
            assert!(a.validate(&g).is_ok(), "{:?}", a.validate(&g));
            for b in &plans[i + 1..] {
                assert!(!plans_overlap(a, b));
            }
        }
    }

    #[test]
    fn expert_fixed_chunk_size() {
        let g = block(256, 16);
        for p in expert_plans(&g, 64) {
            let ext = p.chunk_extent(&g);
            assert_eq!(p.n_chunks, ext.div_ceil(64));
        }
    }

    #[test]
    fn expert_chunked_execution_correct() {
        let g = block(128, 8);
        let plans = expert_plans(&g, 32);
        assert!(!plans.is_empty());
        let ins = random_inputs(&g, 3, None);
        let ps = random_params(&g, 4);
        let t0 = MemoryTracker::new();
        let (base, _) = execute(&g, &ins, &ps, &t0);
        let t1 = MemoryTracker::new();
        let (got, _) = execute_chunked(&g, &plans, &ins, &ps, &t1);
        assert!(base[0].max_abs_diff(&got[0]) < 1e-4);
    }

    #[test]
    fn small_modules_skipped() {
        let g = block(32, 8); // extent 32 <= chunk_size 64
        let plans = expert_plans(&g, 64);
        assert!(plans.is_empty());
    }
}
