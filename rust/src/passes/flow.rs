//! Chunk-flow dimension propagation (paper §3.3, "Chunk Flow").
//!
//! A chunk flow is the path a chunk dimension takes through consecutive
//! nodes. The search pass walks flows *bottom-up* (output → inputs); for
//! each (node, output-dim) pair, [`propagate_to_input`] answers, per input:
//!
//! * [`FlowResult::Dim`] — the input carries the flow at this dimension;
//! * [`FlowResult::NotCarried`] — the input does not participate in the
//!   chunk dimension (broadcast operand, weight side of a matmul); it may
//!   be a non-chunkable input `X^nc` of the region;
//! * [`FlowResult::Broken`] — the op destroys the flow at this dimension
//!   (reduction over it, softmax axis, reshape mixing it, contraction);
//!   a region containing this edge is illegal for this chunk setting
//!   (Rule 3: flow traceability).

use crate::ir::{Graph, NodeId, Op};

/// Outcome of pushing a chunk dimension across one node input edge.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FlowResult {
    /// Input carries the flow at this dimension index.
    Dim(usize),
    /// Input does not carry the chunk dimension (legal as a whole operand).
    NotCarried,
    /// Flow broken: chunking this output dimension is illegal through here.
    Broken,
}

/// Push the chunk dim `out_dim` of `node`'s output backwards onto input
/// `input_pos`. See module docs for semantics.
pub fn propagate_to_input(
    graph: &Graph,
    node: NodeId,
    out_dim: usize,
    input_pos: usize,
) -> FlowResult {
    use FlowResult::*;
    let n = graph.node(node);
    debug_assert!(out_dim < n.shape.len().max(1));
    let in_id = n.inputs[input_pos];
    let in_shape = &graph.node(in_id).shape;
    let out_shape = &n.shape;

    match &n.op {
        Op::Input | Op::Param | Op::Const(_) | Op::Iota { .. } => Broken, // leaves have no inputs

        Op::Binary(_) => {
            // numpy broadcasting: align trailing dims.
            let pad = out_shape.len() - in_shape.len();
            if out_dim < pad {
                return NotCarried;
            }
            let d = out_dim - pad;
            if in_shape[d] == out_shape[out_dim] {
                Dim(d)
            } else {
                debug_assert_eq!(in_shape[d], 1);
                NotCarried
            }
        }

        Op::Unary(_) | Op::Convert => Dim(out_dim),

        Op::Softmax { axis } => {
            if out_dim == *axis {
                Broken
            } else {
                Dim(out_dim)
            }
        }

        Op::MatMul => {
            let out_rank = out_shape.len();
            let in_rank = in_shape.len();
            if out_dim == out_rank - 2 {
                // M: carried by lhs only
                if input_pos == 0 { Dim(in_rank - 2) } else { NotCarried }
            } else if out_dim == out_rank - 1 {
                // N: carried by rhs only
                if input_pos == 1 { Dim(in_rank - 1) } else { NotCarried }
            } else {
                // batch dim, broadcast-aligned from the right of the batch part
                let out_batch = out_rank - 2;
                let in_batch = in_rank - 2;
                let pad = out_batch - in_batch.min(out_batch);
                if out_dim < pad {
                    return NotCarried;
                }
                let d = out_dim - pad;
                if in_shape[d] == out_shape[out_dim] {
                    Dim(d)
                } else {
                    NotCarried // extent-1 broadcast batch
                }
            }
        }

        Op::DotGeneral {
            lhs_batch,
            rhs_batch,
            lhs_contract,
            rhs_contract,
        } => {
            // output dims: [batch..., lhs_free..., rhs_free...]
            let lhs_shape = &graph.node(n.inputs[0]).shape;
            let rhs_shape = &graph.node(n.inputs[1]).shape;
            let lhs_free: Vec<usize> = (0..lhs_shape.len())
                .filter(|d| !lhs_batch.contains(d) && !lhs_contract.contains(d))
                .collect();
            let rhs_free: Vec<usize> = (0..rhs_shape.len())
                .filter(|d| !rhs_batch.contains(d) && !rhs_contract.contains(d))
                .collect();
            let nb = lhs_batch.len();
            if out_dim < nb {
                // batch dim
                if input_pos == 0 {
                    Dim(lhs_batch[out_dim])
                } else {
                    Dim(rhs_batch[out_dim])
                }
            } else if out_dim < nb + lhs_free.len() {
                if input_pos == 0 {
                    Dim(lhs_free[out_dim - nb])
                } else {
                    NotCarried
                }
            } else {
                if input_pos == 1 {
                    Dim(rhs_free[out_dim - nb - lhs_free.len()])
                } else {
                    NotCarried
                }
            }
        }

        Op::Transpose { perm } => Dim(perm[out_dim]),

        Op::Reshape => {
            // out_dim maps cleanly iff some input dim has the same extent
            // AND the same suffix product (i.e. the dimension boundary is
            // preserved by the reshape). Otherwise the reshape mixes the
            // chunk dim with neighbours and the flow breaks.
            let suffix = |shape: &[usize], d: usize| -> usize {
                shape[d + 1..].iter().product()
            };
            let out_suf = suffix(out_shape, out_dim);
            for (j, &ext) in in_shape.iter().enumerate() {
                if ext == out_shape[out_dim] && suffix(in_shape, j) == out_suf {
                    return Dim(j);
                }
            }
            Broken
        }

        Op::Broadcast { dims } => {
            // dims[i] = output dim that input dim i maps to.
            for (i, &d) in dims.iter().enumerate() {
                if d == out_dim {
                    return if in_shape[i] == out_shape[out_dim] {
                        Dim(i)
                    } else {
                        NotCarried // extent-1 broadcast
                    };
                }
            }
            NotCarried // new dim introduced by the broadcast
        }

        Op::Reduce { axis, keepdims, .. } => {
            if input_pos != 0 {
                return NotCarried; // init operand (imported HLO)
            }
            if *keepdims {
                if out_dim == *axis {
                    // chunking the kept reduced dim (extent 1) is degenerate
                    Broken
                } else {
                    Dim(out_dim)
                }
            } else {
                // output dims skip the reduced axis
                let in_dim = if out_dim < *axis { out_dim } else { out_dim + 1 };
                Dim(in_dim)
            }
        }

        Op::Concat { axis } => {
            if out_dim == *axis {
                Broken
            } else {
                Dim(out_dim)
            }
        }

        Op::Slice { axis, .. } => {
            if out_dim == *axis {
                // chunking a sliced dim would need per-chunk offsets
                Broken
            } else {
                Dim(out_dim)
            }
        }

        Op::Gather => {
            // out = ids.shape ++ [D]; input 0 = table [V, D], input 1 = ids.
            let ids_rank = graph.node(n.inputs[1]).shape.len();
            if out_dim < ids_rank {
                if input_pos == 1 { Dim(out_dim) } else { NotCarried }
            } else {
                // embedding dim: slicing the table (a leaf param) is not a
                // chunk flow (leaves are non-chunkable).
                Broken
            }
        }

        Op::Conv2d { .. } => {
            match out_dim {
                0 => {
                    if input_pos == 0 { Dim(0) } else { NotCarried }
                }
                // channel/spatial dims: halo + channel mixing break the flow
                _ => Broken,
            }
        }

        Op::FusedAttention { .. } => {
            let out_rank = out_shape.len();
            if input_pos == 3 {
                // optional q_pos [sq]: rides the query-row dim with q so
                // causal masking slices consistently under chunking
                return if out_dim == out_rank - 2 { Dim(0) } else { NotCarried };
            }
            if out_dim == out_rank - 2 {
                // query rows: carried by q only
                if input_pos == 0 { Dim(in_shape.len() - 2) } else { NotCarried }
            } else if out_dim == out_rank - 1 {
                // value columns: carried by v only
                if input_pos == 2 { Dim(in_shape.len() - 1) } else { NotCarried }
            } else {
                // batch dims, broadcast-aligned
                let in_batch = in_shape.len() - 2;
                let pad = (out_rank - 2) - in_batch.min(out_rank - 2);
                if out_dim < pad {
                    return NotCarried;
                }
                let d = out_dim - pad;
                if in_shape[d] == out_shape[out_dim] { Dim(d) } else { NotCarried }
            }
        }

        // Conservative: unknown semantics can never carry a chunk flow.
        Op::Opaque { .. } => Broken,

        Op::AvgPool2x | Op::Upsample2x => {
            // batch and channel dims flow; spatial dims are resampled
            if out_dim <= 1 { Dim(out_dim) } else { Broken }
        }
    }
}

/// Smallest stride class of `dim` within `shape` — 0 for the innermost
/// (unit-stride) dimension, rank-1 for the outermost. Used by the micro
/// cost term: chunking large-stride (outer) dims is cheap, small-stride
/// (inner) dims forces scattered copies.
pub fn dim_stride_elems(shape: &[usize], dim: usize) -> usize {
    shape[dim + 1..].iter().product::<usize>().max(1)
}

#[cfg(test)]
mod tests {
    use super::FlowResult::*;
    use super::*;
    use crate::ir::GraphBuilder;
    use crate::tensor::ops::{BinaryOp, UnaryOp};
    use crate::tensor::reduce::ReduceOp;

    #[test]
    fn unary_passes_all_dims() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let y = b.unary(UnaryOp::Relu, x);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(1));
    }

    #[test]
    fn binary_broadcast_bias_not_carried() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let bias = b.input("b", &[8]);
        let y = b.binary(BinaryOp::Add, x, bias);
        let g = b.finish(vec![y]);
        // dim 0 (the broadcast dim): x carries, bias does not
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 0, 1), NotCarried);
        // dim 1: both carry
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(1));
        assert_eq!(propagate_to_input(&g, y, 1, 1), Dim(0));
    }

    #[test]
    fn binary_keepdims_side_not_carried() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let m = b.reduce(ReduceOp::Max, x, 1, true); // [4,1]
        let y = b.sub(x, m);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 1), Dim(0)); // 4 == 4
        assert_eq!(propagate_to_input(&g, y, 1, 1), NotCarried); // extent 1
    }

    #[test]
    fn matmul_row_col_and_batch() {
        let mut b = GraphBuilder::new("t");
        let a = b.input("a", &[2, 16, 32]);
        let w = b.input("w", &[2, 32, 8]);
        let y = b.matmul(a, w);
        let g = b.finish(vec![y]);
        // batch dim 0 carried by both
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 0, 1), Dim(0));
        // M dim (1): lhs only
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(1));
        assert_eq!(propagate_to_input(&g, y, 1, 1), NotCarried);
        // N dim (2): rhs only
        assert_eq!(propagate_to_input(&g, y, 2, 0), NotCarried);
        assert_eq!(propagate_to_input(&g, y, 2, 1), Dim(2));
    }

    #[test]
    fn matmul_2d_weight_broadcast_batch() {
        let mut b = GraphBuilder::new("t");
        let a = b.input("a", &[6, 16, 32]);
        let w = b.input("w", &[32, 8]);
        let y = b.matmul(a, w); // [6,16,8]
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 0, 1), NotCarried);
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(1));
        assert_eq!(propagate_to_input(&g, y, 2, 1), Dim(1));
    }

    #[test]
    fn softmax_axis_breaks() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let y = b.softmax(x, 1);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 1, 0), Broken);
    }

    #[test]
    fn transpose_permutes_flow() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8, 16]);
        let y = b.transpose(x, &[2, 0, 1]);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(2));
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 2, 0), Dim(1));
    }

    #[test]
    fn reshape_preserved_boundary_flows() {
        // [B, S, H*D] -> [B, S, H, D]: dims B and S map; H and D are new
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 16, 32]);
        let y = b.reshape(x, &[2, 16, 4, 8]);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0)); // B
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(1)); // S
        assert_eq!(propagate_to_input(&g, y, 2, 0), Broken); // H (split from H*D)
        assert_eq!(propagate_to_input(&g, y, 3, 0), Broken); // D
    }

    #[test]
    fn reshape_merge_breaks_merged_dim() {
        // [4, 8] -> [32]: the merged dim mixes both — broken
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let y = b.reshape(x, &[32]);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Broken);
    }

    #[test]
    fn reshape_flatten_leading_keeps_trailing() {
        // [2,3,32] -> [6,32]: trailing dim maps (same suffix), leading broken
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 32]);
        let y = b.reshape(x, &[6, 32]);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(2));
        assert_eq!(propagate_to_input(&g, y, 0, 0), Broken);
    }

    #[test]
    fn reduce_skips_axis() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8, 16]);
        let y = b.reduce(ReduceOp::Sum, x, 1, false); // [4,16]
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 1, 0), Dim(2));
    }

    #[test]
    fn reduce_keepdims_axis_degenerate() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let y = b.reduce(ReduceOp::Sum, x, 1, true); // [4,1]
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 1, 0), Broken);
    }

    #[test]
    fn concat_and_slice_axis_break() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let y = b.input("y", &[4, 8]);
        let c = b.concat(&[x, y], 1);
        let s = b.slice(c, 0, 0, 2);
        let g = b.finish(vec![s]);
        assert_eq!(propagate_to_input(&g, c, 1, 0), Broken);
        assert_eq!(propagate_to_input(&g, c, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, s, 0, 0), Broken);
        assert_eq!(propagate_to_input(&g, s, 1, 0), Dim(1));
    }

    #[test]
    fn conv_batch_flows_spatial_breaks() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3, 8, 8]);
        let w = b.param("w", &[4, 3, 3, 3]);
        let y = b.conv2d(x, w, 1, 1);
        let g = b.finish(vec![y]);
        assert_eq!(propagate_to_input(&g, y, 0, 0), Dim(0));
        assert_eq!(propagate_to_input(&g, y, 0, 1), NotCarried);
        assert_eq!(propagate_to_input(&g, y, 1, 0), Broken);
        assert_eq!(propagate_to_input(&g, y, 2, 0), Broken);
    }

    #[test]
    fn gather_ids_flow() {
        let mut b = GraphBuilder::new("t");
        let table = b.param("tbl", &[100, 16]);
        let ids = b.input_i32("ids", &[4, 8]);
        let e = b.gather(table, ids);
        let g = b.finish(vec![e]);
        assert_eq!(propagate_to_input(&g, e, 0, 1), Dim(0));
        assert_eq!(propagate_to_input(&g, e, 1, 1), Dim(1));
        assert_eq!(propagate_to_input(&g, e, 0, 0), NotCarried);
        assert_eq!(propagate_to_input(&g, e, 2, 0), Broken);
    }

    #[test]
    fn stride_elems() {
        assert_eq!(dim_stride_elems(&[4, 8, 16], 0), 128);
        assert_eq!(dim_stride_elems(&[4, 8, 16], 1), 16);
        assert_eq!(dim_stride_elems(&[4, 8, 16], 2), 1);
    }
}
