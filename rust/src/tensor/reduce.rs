//! Reductions and softmax.
//!
//! Both kernels move the target axis innermost and then process
//! independent rows; rows partition over the worker pool in contiguous
//! blocks, each owning a disjoint output slab — bitwise identical to the
//! serial path at every width.

use super::{MemoryTracker, Tensor};
use crate::util::pool;

/// Reduction operator.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
    Mean,
}

impl ReduceOp {
    pub fn name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Max => "rmax",
            ReduceOp::Min => "rmin",
            ReduceOp::Mean => "mean",
        }
    }
}

/// Shape after reducing `axis` (keepdims keeps a 1).
pub fn reduce_shape(shape: &[usize], axis: usize, keepdims: bool) -> Vec<usize> {
    let mut out = shape.to_vec();
    if keepdims {
        out[axis] = 1;
    } else {
        out.remove(axis);
    }
    out
}

/// Core of [`reduce`]: reduces into `out` (length = row count) and
/// returns the output shape. The permuted materialization of a
/// non-innermost axis stays transient workspace on `tracker`.
pub fn reduce_into(
    op: ReduceOp,
    a: &Tensor,
    axis: usize,
    keepdims: bool,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert!(axis < a.rank(), "reduce axis out of range");
    let shape = a.shape().to_vec();
    let out_shape = reduce_shape(&shape, axis, keepdims);
    let red_n = shape[axis];

    // Move the reduction axis last, materialize, then reduce rows.
    let mut perm: Vec<usize> = (0..a.rank()).filter(|&i| i != axis).collect();
    perm.push(axis);
    let pa = a.permute(&perm).to_contiguous(tracker);
    let src = pa.f32_contiguous();
    let rows = pa.numel() / red_n;
    assert_eq!(out.len(), rows, "reduce_into length mismatch");
    pool::par_rows(out, rows, 1, pa.numel(), |r0, _r1, slab| {
        for (j, o) in slab.iter_mut().enumerate() {
            let r = r0 + j;
            let row = &src[r * red_n..(r + 1) * red_n];
            *o = match op {
                ReduceOp::Sum => row.iter().sum::<f32>(),
                ReduceOp::Mean => row.iter().sum::<f32>() / red_n as f32,
                ReduceOp::Max => row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
                ReduceOp::Min => row.iter().copied().fold(f32::INFINITY, f32::min),
            };
        }
    });
    out_shape
}

/// Reduce along a single axis.
pub fn reduce(
    op: ReduceOp,
    a: &Tensor,
    axis: usize,
    keepdims: bool,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let rows = a.numel() / a.shape()[axis];
    let mut out = vec![0.0f32; rows];
    let out_shape = reduce_into(op, a, axis, keepdims, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Row-wise numerically-stable softmax over `rows` rows of `n` elements.
/// Shared by the allocating and into-slot softmax paths so both are
/// bitwise identical.
fn softmax_rows(src: &[f32], out: &mut [f32], rows: usize, n: usize) {
    pool::par_rows(out, rows, n, src.len() * 4, |r0, _r1, slab| {
        for (j, orow) in slab.chunks_mut(n).enumerate() {
            let r = r0 + j;
            let row = &src[r * n..(r + 1) * n];
            let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                let e = (x - m).exp();
                *o = e;
                denom += e;
            }
            let inv = 1.0 / denom;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    });
}

/// Numerically-stable softmax along `axis`.
pub fn softmax(a: &Tensor, axis: usize, tracker: Option<MemoryTracker>) -> Tensor {
    assert!(axis < a.rank());
    // Move axis last, materialize, softmax rows, move back.
    let mut perm: Vec<usize> = (0..a.rank()).filter(|&i| i != axis).collect();
    perm.push(axis);
    let pa = a.permute(&perm).to_contiguous(tracker.clone());
    let src = pa.f32_contiguous();
    let n = pa.shape()[pa.rank() - 1];
    let rows = pa.numel() / n;
    let mut out = vec![0.0f32; pa.numel()];
    softmax_rows(src, &mut out, rows, n);
    let t = Tensor::from_f32(out, pa.shape(), tracker.clone());
    // Inverse permutation restores the original layout.
    let mut inv_perm = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv_perm[p] = i;
    }
    t.permute(&inv_perm).to_contiguous(tracker)
}

/// Core of [`softmax`] for planned-slot output: writes the softmax in the
/// *original* layout (row-major) into `out`. With the axis innermost over
/// a contiguous input (the common transformer case) rows are computed
/// directly into `out`; otherwise the permuted intermediate is computed in
/// scratch — registered on `tracker` like every other kernel workspace,
/// so admission accounting sees it — and inverse-permuted into `out`.
pub fn softmax_into(a: &Tensor, axis: usize, out: &mut [f32], tracker: Option<MemoryTracker>) {
    assert!(axis < a.rank());
    assert_eq!(out.len(), a.numel(), "softmax_into length mismatch");
    let mut perm: Vec<usize> = (0..a.rank()).filter(|&i| i != axis).collect();
    perm.push(axis);
    let pa = a.permute(&perm).to_contiguous(tracker.clone());
    let src = pa.f32_contiguous();
    let n = pa.shape()[pa.rank() - 1];
    let rows = pa.numel() / n;
    if axis == a.rank() - 1 {
        // perm is the identity: the permuted layout IS the output layout.
        softmax_rows(src, out, rows, n);
        return;
    }
    let mut tmp = vec![0.0f32; pa.numel()];
    softmax_rows(src, &mut tmp, rows, n);
    let t = Tensor::from_f32(tmp, pa.shape(), tracker);
    let mut inv_perm = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv_perm[p] = i;
    }
    t.permute(&inv_perm).copy_into_f32(out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_f32(data.to_vec(), shape, None)
    }

    #[test]
    fn sum_axes() {
        let a = t(&[1., 2., 3., 4., 5., 6.], &[2, 3]);
        assert_eq!(
            reduce(ReduceOp::Sum, &a, 1, false, None).to_vec_f32(),
            vec![6., 15.]
        );
        assert_eq!(
            reduce(ReduceOp::Sum, &a, 0, false, None).to_vec_f32(),
            vec![5., 7., 9.]
        );
    }

    #[test]
    fn keepdims_shape() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]);
        let r = reduce(ReduceOp::Sum, &a, 1, true, None);
        assert_eq!(r.shape(), &[2, 1]);
        let r2 = reduce(ReduceOp::Sum, &a, 1, false, None);
        assert_eq!(r2.shape(), &[2]);
    }

    #[test]
    fn max_min_mean() {
        let a = t(&[1., 5., -2., 0.], &[2, 2]);
        assert_eq!(
            reduce(ReduceOp::Max, &a, 1, false, None).to_vec_f32(),
            vec![5., 0.]
        );
        assert_eq!(
            reduce(ReduceOp::Min, &a, 1, false, None).to_vec_f32(),
            vec![1., -2.]
        );
        assert_eq!(
            reduce(ReduceOp::Mean, &a, 1, false, None).to_vec_f32(),
            vec![3., -1.]
        );
    }

    #[test]
    fn reduce_middle_axis() {
        let a = Tensor::iota(&[2, 3, 4], 1, None); // values 0,1,2 along axis 1
        let r = reduce(ReduceOp::Sum, &a, 1, false, None);
        assert_eq!(r.shape(), &[2, 4]);
        assert_eq!(r.to_vec_f32(), vec![3.0; 8]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = Tensor::rand(&[4, 7], 3.0, 9, None);
        let s = softmax(&a, 1, None);
        for r in 0..4 {
            let row_sum: f32 = s.slice_axis(0, r, 1).to_vec_f32().iter().sum();
            assert!((row_sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_axis0_matches_transpose() {
        let a = Tensor::rand(&[3, 5], 2.0, 11, None);
        let s0 = softmax(&a, 0, None);
        let s1 = softmax(&a.permute(&[1, 0]), 1, None).permute(&[1, 0]);
        assert!(s0.max_abs_diff(&s1.to_contiguous(None)) < 1e-6);
    }

    #[test]
    fn softmax_stable_for_large_values() {
        let a = t(&[1000., 1001., 1002.], &[1, 3]);
        let s = softmax(&a, 1, None).to_vec_f32();
        assert!(s.iter().all(|v| v.is_finite()));
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn softmax_known_values() {
        let a = t(&[0., 0.], &[1, 2]);
        assert_eq!(softmax(&a, 1, None).to_vec_f32(), vec![0.5, 0.5]);
    }
}
