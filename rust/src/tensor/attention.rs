//! Fused memory-efficient attention (Rabe & Staats 2022).
//!
//! `softmax(q·kᵀ·scale)·v` computed by streaming over key/value blocks with
//! a running max and denominator, so the `[s_q, s_kv]` score matrix is
//! never materialized — peak workspace is `O(s_q·(d + B))` instead of
//! `O(s_q·s_kv)`. This is the "fused attention kernel" baseline of the
//! paper's Figure 6 (and the CPU twin of the L1 Pallas kernel in
//! `python/compile/kernels/attention.py`).
//!
//! Two extensions serve the autoregressive decode path (DESIGN.md §13):
//!
//! * **position masking** (`fused_attention_pos*`): an optional `q_pos`
//!   tensor gives each query row its absolute position; key index `j` is
//!   attended iff `j ≤ q_pos[i]`. Masked entries are *exact no-ops* in the
//!   online-softmax stream (they never change the running max, the
//!   denominator, or the accumulator), so a causally-masked prefill row is
//!   bitwise identical to the same row attending only its prefix. Because
//!   `q_pos` is a data input it slices with `q` under chunked execution,
//!   so chunked causal prefill stays bitwise exact too.
//! * **incremental attention** (`incremental_attention*`): the decode-step
//!   kernel — one (or a few) query rows against a KV cache. It *is* the
//!   fused core (every query row's stream is independent), which is the
//!   whole bitwise-parity guarantee: calling it with one row produces
//!   exactly the bits full fused attention produces for that row.

use super::{broadcast_shapes, MemoryTracker, Tensor};
use crate::util::pool;

/// Key/value block length for the streaming pass.
pub const KV_BLOCK: usize = 64;

/// Shared streaming core: computes batched fused attention into `out`,
/// optionally restricting each query row `i` to key indices
/// `j ≤ q_pos[i]` (position masking). Returns the output shape.
///
/// Masked entries are represented as `-∞` scores and skipped in the
/// update loop: they contribute exactly nothing to the running max,
/// denominator, or accumulator, so the processed stream is bitwise
/// identical to running the same row over only its allowed prefix with
/// the same block partition.
fn fused_attention_core(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    q_pos: Option<&Tensor>,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert!(q.rank() >= 2);
    let rank = q.rank();
    let (sq, d) = (q.shape()[rank - 2], q.shape()[rank - 1]);
    let skv = k.shape()[k.rank() - 2];
    assert_eq!(k.shape()[k.rank() - 1], d, "k head dim");
    assert_eq!(v.shape()[v.rank() - 2], skv, "v rows");
    let dv = v.shape()[v.rank() - 1];

    let batch_shape = broadcast_shapes(
        &broadcast_shapes(&q.shape()[..rank - 2], &k.shape()[..k.rank() - 2]),
        &v.shape()[..v.rank() - 2],
    );
    let batch: usize = batch_shape.iter().product::<usize>().max(1);

    let mut qs = batch_shape.clone();
    qs.extend_from_slice(&[sq, d]);
    let mut ks = batch_shape.clone();
    ks.extend_from_slice(&[skv, d]);
    let mut vs = batch_shape.clone();
    vs.extend_from_slice(&[skv, dv]);
    let qc = q.broadcast_to(&qs).to_contiguous(tracker.clone());
    let kc = k.broadcast_to(&ks).to_contiguous(tracker.clone());
    let vc = v.broadcast_to(&vs).to_contiguous(tracker.clone());
    let qv = qc.f32_contiguous();
    let kv = kc.f32_contiguous();
    let vv = vc.f32_contiguous();
    // Positions are per query row, shared across the batch.
    let pos_c = q_pos.map(|p| {
        assert_eq!(p.numel(), sq, "q_pos must hold one position per query row");
        p.to_contiguous(tracker)
    });
    let pos_v: Option<&[f32]> = pos_c.as_ref().map(|p| p.f32_contiguous());

    assert_eq!(out.len(), batch * sq * dv, "fused_attention length mismatch");
    // Every query row's online-softmax stream is independent of every
    // other row, so rows partition over the pool *within* each batch
    // element; each worker carries its own running max/denominator and
    // score scratch. The kv-block order per row is untouched, so results
    // are bitwise identical to the serial stream at any width.
    // Per-batch-element work: each par_rows call below covers one batch
    // element, so the inline-threshold decision must not be inflated by
    // the batch count.
    let work = sq * skv * (d + dv);
    for bi in 0..batch {
        let qm = &qv[bi * sq * d..(bi + 1) * sq * d];
        let km = &kv[bi * skv * d..(bi + 1) * skv * d];
        let vm = &vv[bi * skv * dv..(bi + 1) * skv * dv];
        let om = &mut out[bi * sq * dv..(bi + 1) * sq * dv];
        pool::par_rows(om, sq, dv, work, |i0, i1, om_slab| {
            let rows = i1 - i0;
            let mut m = vec![f32::NEG_INFINITY; rows];
            let mut l = vec![0.0f32; rows];
            let mut scores = vec![0.0f32; rows * KV_BLOCK];

            let mut blk = 0usize;
            while blk < skv {
                let bk = KV_BLOCK.min(skv - blk);
                // scores = q @ k_blk^T * scale (masked entries get -inf
                // without touching the k data — position masking must be
                // independent of whatever bytes sit in masked cache rows)
                for i in 0..rows {
                    let qr = &qm[(i0 + i) * d..(i0 + i + 1) * d];
                    let limit = pos_v.map(|p| p[i0 + i]);
                    for j in 0..bk {
                        let masked =
                            matches!(limit, Some(lim) if (blk + j) as f32 > lim);
                        scores[i * bk + j] = if masked {
                            f32::NEG_INFINITY
                        } else {
                            let kr = &km[(blk + j) * d..(blk + j + 1) * d];
                            let mut acc = 0.0f32;
                            for p in 0..d {
                                acc += qr[p] * kr[p];
                            }
                            acc * scale
                        };
                    }
                }
                // online softmax update
                for i in 0..rows {
                    let row = &scores[i * bk..i * bk + bk];
                    let blk_max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    if blk_max == f32::NEG_INFINITY {
                        // fully-masked block: exact no-op (the running
                        // state is what it would be had the block never
                        // been streamed)
                        continue;
                    }
                    let new_m = m[i].max(blk_max);
                    let correction = if m[i].is_finite() { (m[i] - new_m).exp() } else { 0.0 };
                    // rescale accumulated output and denominator
                    if correction != 1.0 {
                        for p in 0..dv {
                            om_slab[i * dv + p] *= correction;
                        }
                        l[i] *= correction;
                    }
                    for j in 0..bk {
                        if row[j] == f32::NEG_INFINITY {
                            continue; // masked: e would be exactly 0
                        }
                        let e = (row[j] - new_m).exp();
                        l[i] += e;
                        let vr = &vm[(blk + j) * dv..(blk + j + 1) * dv];
                        for p in 0..dv {
                            om_slab[i * dv + p] += e * vr[p];
                        }
                    }
                    m[i] = new_m;
                }
                blk += bk;
            }
            // normalize
            for i in 0..rows {
                let inv = 1.0 / l[i];
                for p in 0..dv {
                    om_slab[i * dv + p] *= inv;
                }
            }
        });
    }

    let mut out_shape = batch_shape;
    out_shape.extend_from_slice(&[sq, dv]);
    out_shape
}

/// Core of [`fused_attention`]: streams into `out` (length batch·sq·dv),
/// returning the output shape. Broadcast/contiguity materialization of
/// q/k/v remains transient workspace on `tracker`; the per-row running
/// max/denominator/score scratch is untracked worker-local state.
pub fn fused_attention_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    fused_attention_core(q, k, v, None, scale, out, tracker)
}

/// As [`fused_attention_into`] with per-query-row position masking:
/// query row `i` attends key index `j` iff `j ≤ q_pos[i]`.
pub fn fused_attention_pos_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    q_pos: &Tensor,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    fused_attention_core(q, k, v, Some(q_pos), scale, out, tracker)
}

/// Batched fused attention. `q: [..b, sq, d]`, `k,v: [..b, skv, d]`.
pub fn fused_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let mut out = vec![0.0f32; fused_out_len3(q, k, v)];
    let out_shape = fused_attention_core(q, k, v, None, scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Batched fused attention with position masking (causal prefill).
pub fn fused_attention_pos(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    q_pos: &Tensor,
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let mut out = vec![0.0f32; fused_out_len3(q, k, v)];
    let out_shape = fused_attention_core(q, k, v, Some(q_pos), scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Output element count of a fused-attention call (batch·sq·dv).
fn fused_out_len3(q: &Tensor, k: &Tensor, v: &Tensor) -> usize {
    let rank = q.rank();
    let (sq, dv) = (q.shape()[rank - 2], v.shape()[v.rank() - 1]);
    let batch: usize = broadcast_shapes(
        &broadcast_shapes(&q.shape()[..rank - 2], &k.shape()[..k.rank() - 2]),
        &v.shape()[..v.rank() - 2],
    )
    .iter()
    .product::<usize>()
    .max(1);
    batch * sq * dv
}

/// Incremental (decode-step) attention core: attend `q` — one or a few
/// query rows — against a KV cache view `k`/`v` of the current logical
/// length, writing into `out`.
///
/// This *is* [`fused_attention_into`]: the online-softmax stream of each
/// query row depends only on that row and the kv prefix, so a single-row
/// call produces bitwise exactly the row a full fused-attention prefill
/// produces (`decode_parity` tests pin this). Kept as a named entry point
/// so the decode path's kernel contract is explicit.
pub fn incremental_attention_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    fused_attention_core(q, k, v, None, scale, out, tracker)
}

/// Allocating wrapper over [`incremental_attention_into`].
pub fn incremental_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let mut out = vec![0.0f32; fused_out_len3(q, k, v)];
    let out_shape = incremental_attention_into(q, k, v, scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Gather the valid `len`-row prefix of a paged cache — `blocks` are
/// `[h, block_tokens, dh]` tensors in block-table order — into one
/// contiguous `[h, len, dh]` tensor on `tracker`.
///
/// Pure data movement: row `p` of head `h` is read from
/// `blocks[p / block_tokens]` at row `p % block_tokens`, so the gathered
/// bytes are exactly the bytes a contiguous cache of the same history
/// holds. Rows past `len` (a partial tail block) are never read.
fn gather_blocks(blocks: &[Tensor], len: usize, tracker: Option<MemoryTracker>) -> Tensor {
    assert!(!blocks.is_empty(), "empty block table");
    assert!(len > 0, "gather of empty prefix");
    let shape = blocks[0].shape().to_vec();
    assert_eq!(shape.len(), 3, "blocks must be [h, block_tokens, dh]");
    let (h, bt, dh) = (shape[0], shape[1], shape[2]);
    assert!(len <= blocks.len() * bt, "len {len} over table capacity");
    let mut out = vec![0.0f32; h * len * dh];
    for (bi, b) in blocks.iter().enumerate() {
        assert_eq!(b.shape(), &shape[..], "ragged block table");
        let r0 = bi * bt;
        if r0 >= len {
            break;
        }
        let rows = bt.min(len - r0);
        // pool blocks are contiguous by construction
        let src = b.f32_contiguous();
        for hi in 0..h {
            let d0 = hi * len * dh + r0 * dh;
            let s0 = hi * bt * dh;
            out[d0..d0 + rows * dh].copy_from_slice(&src[s0..s0 + rows * dh]);
        }
    }
    Tensor::from_f32(out, &[h, len, dh], tracker)
}

/// Block-table-indirect decode attention: attend `q` — one (or a few)
/// query rows per head — against the first `len` cached positions of a
/// *paged* KV cache, reading K/V through per-layer block lists instead of
/// one contiguous cache tensor.
///
/// Bitwise contract: the gathered prefix holds exactly the bytes the
/// contiguous cache view holds (gathering is pure data movement), and the
/// compute is the shared fused online-softmax core — so the output is
/// bitwise identical to [`incremental_attention`] over the equivalent
/// contiguous cache (`rust/tests/kvpage_fuzz.rs` pins this across block
/// sizes and `KV_BLOCK` boundaries). The gathered copies are transient
/// workspace on `tracker`, mirroring what `incremental_attention` itself
/// pays to contiguate a strided cache view.
pub fn paged_attention_into(
    q: &Tensor,
    k_blocks: &[Tensor],
    v_blocks: &[Tensor],
    len: usize,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    let kc = gather_blocks(k_blocks, len, tracker.clone());
    let vc = gather_blocks(v_blocks, len, tracker.clone());
    fused_attention_core(q, &kc, &vc, None, scale, out, tracker)
}

/// Allocating wrapper over [`paged_attention_into`].
pub fn paged_attention(
    q: &Tensor,
    k_blocks: &[Tensor],
    v_blocks: &[Tensor],
    len: usize,
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let kc = gather_blocks(k_blocks, len, tracker.clone());
    let vc = gather_blocks(v_blocks, len, tracker.clone());
    let mut out = vec![0.0f32; fused_out_len3(q, &kc, &vc)];
    let out_shape = fused_attention_core(q, &kc, &vc, None, scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Multi-query paged decode attention: one query row per request, each
/// attending its *own* paged KV cache at its *own* length. `q` is
/// `[h, n, dh]` (the batched decode graph's head-split query stack);
/// request `r` reads column `r` of `q`, the block table
/// `k_tables[r]`/`v_tables[r]`, and attends key indices `j < lens[r]`.
/// Writes `[h, n, dh]` — column `r` is request `r`'s context row.
///
/// Ragged lengths are handled by **position masking**, not by trimming:
/// each request's table is gathered at full held capacity and streamed
/// through the fused core with `q_pos = lens[r] − 1`, so tail rows ride
/// the same exact online-softmax no-op rule as the causal prefill kernel.
/// Because masked entries contribute exactly nothing to the running
/// max/denominator/accumulator and the `KV_BLOCK` partition of the valid
/// prefix is unchanged, each column is bitwise identical to the
/// single-request [`paged_attention`] over the same table (pinned by the
/// tests below).
pub fn paged_attention_batched_into(
    q: &Tensor,
    k_tables: &[Vec<Tensor>],
    v_tables: &[Vec<Tensor>],
    lens: &[usize],
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert_eq!(q.rank(), 3, "q must be [h, n, dh]");
    let (h, n, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    assert_eq!(k_tables.len(), n, "one K table per query row");
    assert_eq!(v_tables.len(), n, "one V table per query row");
    assert_eq!(lens.len(), n, "one length per query row");
    assert_eq!(out.len(), h * n * dh, "paged_attention_batched length mismatch");
    let mut buf = vec![0.0f32; h * dh];
    for r in 0..n {
        let len = lens[r];
        assert!(len > 0, "request {r}: decode needs a non-empty cache");
        let qr = q.slice_axis(1, r, 1).to_contiguous(tracker.clone()); // [h, 1, dh]
        let bt = k_tables[r][0].shape()[1];
        let cap = k_tables[r].len() * bt;
        assert!(len <= cap, "request {r}: len {len} over table capacity {cap}");
        let kc = gather_blocks(&k_tables[r], cap, tracker.clone());
        let vc = gather_blocks(&v_tables[r], cap, tracker.clone());
        let pos = Tensor::from_f32(vec![(len - 1) as f32], &[1], tracker.clone());
        buf.fill(0.0);
        fused_attention_core(&qr, &kc, &vc, Some(&pos), scale, &mut buf, tracker.clone());
        for hi in 0..h {
            out[hi * n * dh + r * dh..hi * n * dh + (r + 1) * dh]
                .copy_from_slice(&buf[hi * dh..(hi + 1) * dh]);
        }
    }
    vec![h, n, dh]
}

/// Allocating wrapper over [`paged_attention_batched_into`].
pub fn paged_attention_batched(
    q: &Tensor,
    k_tables: &[Vec<Tensor>],
    v_tables: &[Vec<Tensor>],
    lens: &[usize],
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let (h, n, dh) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let mut out = vec![0.0f32; h * n * dh];
    let shape =
        paged_attention_batched_into(q, k_tables, v_tables, lens, scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &shape, tracker)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;
    use crate::tensor::reduce::softmax;

    /// Dense reference: softmax(q k^T scale) v.
    fn dense_attention(q: &Tensor, k: &Tensor, v: &Tensor, scale: f32) -> Tensor {
        let rank = k.rank();
        let mut perm: Vec<usize> = (0..rank).collect();
        perm.swap(rank - 1, rank - 2);
        let kt = k.permute(&perm);
        let scores = matmul(q, &kt, None);
        let scaled = crate::tensor::ops::binary_scalar(
            crate::tensor::ops::BinaryOp::Mul,
            &scores,
            scale,
            None,
        );
        let probs = softmax(&scaled, scaled.rank() - 1, None);
        matmul(&probs, v, None)
    }

    #[test]
    fn matches_dense_reference_2d() {
        for &(sq, skv, d) in &[(16, 16, 8), (33, 100, 4), (8, 200, 16)] {
            let q = Tensor::rand(&[sq, d], 1.0, 1, None);
            let k = Tensor::rand(&[skv, d], 1.0, 2, None);
            let v = Tensor::rand(&[skv, d], 1.0, 3, None);
            let got = fused_attention(&q, &k, &v, 0.3, None);
            let want = dense_attention(&q, &k, &v, 0.3);
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "({sq},{skv},{d}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn matches_dense_reference_batched() {
        let q = Tensor::rand(&[4, 32, 8], 1.0, 5, None);
        let k = Tensor::rand(&[4, 96, 8], 1.0, 6, None);
        let v = Tensor::rand(&[4, 96, 8], 1.0, 7, None);
        let got = fused_attention(&q, &k, &v, 0.35, None);
        let want = dense_attention(&q, &k, &v, 0.35);
        assert_eq!(got.shape(), &[4, 32, 8]);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn single_block_path() {
        // skv < KV_BLOCK exercises the tail-only path
        let q = Tensor::rand(&[5, 4], 1.0, 8, None);
        let k = Tensor::rand(&[7, 4], 1.0, 9, None);
        let v = Tensor::rand(&[7, 4], 1.0, 10, None);
        let got = fused_attention(&q, &k, &v, 1.0, None);
        let want = dense_attention(&q, &k, &v, 1.0);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn numerically_stable_large_logits() {
        let q = Tensor::rand(&[4, 8], 30.0, 11, None);
        let k = Tensor::rand(&[128, 8], 30.0, 12, None);
        let v = Tensor::rand(&[128, 8], 1.0, 13, None);
        let got = fused_attention(&q, &k, &v, 1.0, None);
        assert!(got.to_vec_f32().iter().all(|x| x.is_finite()));
        let want = dense_attention(&q, &k, &v, 1.0);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    /// Causal (position-masked) prefill row i is bitwise identical to the
    /// unmasked kernel run over only its prefix `k[0..=i]` — across block
    /// boundaries (skv spans multiple KV_BLOCKs).
    #[test]
    fn causal_rows_match_prefix_attention_bitwise() {
        let (s, d) = (150, 8); // > 2 KV_BLOCKs with a ragged tail
        let q = Tensor::rand(&[s, d], 1.0, 21, None);
        let k = Tensor::rand(&[s, d], 1.0, 22, None);
        let v = Tensor::rand(&[s, d], 1.0, 23, None);
        let pos = Tensor::from_f32((0..s).map(|i| i as f32).collect(), &[s], None);
        let causal = fused_attention_pos(&q, &k, &v, &pos, 0.25, None);
        for i in [0usize, 1, 5, 63, 64, 65, 127, 128, 149] {
            let qi = q.slice_axis(0, i, 1).to_contiguous(None);
            let ki = k.slice_axis(0, 0, i + 1).to_contiguous(None);
            let vi = v.slice_axis(0, 0, i + 1).to_contiguous(None);
            let row = incremental_attention(&qi, &ki, &vi, 0.25, None);
            let want: Vec<u32> =
                causal.slice_axis(0, i, 1).to_vec_f32().iter().map(|x| x.to_bits()).collect();
            let got: Vec<u32> = row.to_vec_f32().iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "row {i} diverged");
        }
    }

    /// Masked entries must be no-ops regardless of the bytes behind them:
    /// poisoning the masked-out tail of k/v must not change any output bit.
    #[test]
    fn masked_tail_bytes_are_irrelevant() {
        let (s, cap, d) = (9, 40, 4);
        let q = Tensor::rand(&[1, d], 1.0, 31, None);
        let kh = Tensor::rand(&[cap, d], 1.0, 32, None);
        let vh = Tensor::rand(&[cap, d], 1.0, 33, None);
        let pos = Tensor::from_f32(vec![(s - 1) as f32], &[1], None);
        let base = fused_attention_pos(&q, &kh, &vh, &pos, 0.5, None).to_vec_f32();

        let poison = |t: &Tensor| {
            let mut v = t.to_vec_f32();
            for x in v.iter_mut().skip(s * d) {
                *x = f32::NAN;
            }
            Tensor::from_f32(v, t.shape(), None)
        };
        let got =
            fused_attention_pos(&q, &poison(&kh), &poison(&vh), &pos, 0.5, None).to_vec_f32();
        let a: Vec<u32> = base.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
    }

    /// Position masking equals the dense additive-mask reference.
    #[test]
    fn causal_matches_dense_masked_reference() {
        let (s, d) = (40, 8);
        let q = Tensor::rand(&[s, d], 1.0, 41, None);
        let k = Tensor::rand(&[s, d], 1.0, 42, None);
        let v = Tensor::rand(&[s, d], 1.0, 43, None);
        let pos = Tensor::from_f32((0..s).map(|i| i as f32).collect(), &[s], None);
        let got = fused_attention_pos(&q, &k, &v, &pos, 0.3, None);

        // dense: scores + (-1e30 per masked cell), softmax, @v
        let kt = k.permute(&[1, 0]);
        let scores = matmul(&q, &kt, None);
        let mut sm = scores.to_vec_f32();
        for i in 0..s {
            for j in 0..s {
                sm[i * s + j] *= 0.3;
                if j > i {
                    sm[i * s + j] = -1e30;
                }
            }
        }
        let probs = softmax(&Tensor::from_f32(sm, &[s, s], None), 1, None);
        let want = matmul(&probs, &v, None);
        assert!(got.max_abs_diff(&want) < 1e-4, "{}", got.max_abs_diff(&want));
    }

    /// The block-table-indirect kernel must be bitwise identical to the
    /// contiguous incremental path at every prefix length, including
    /// lengths that straddle both block_tokens and KV_BLOCK boundaries.
    #[test]
    fn paged_attention_matches_incremental_bitwise() {
        let (h, dh) = (2usize, 8usize);
        for &bt in &[16usize, 48, 64] {
            let cap = 3 * bt; // three blocks
            let kfull = Tensor::rand(&[h, cap, dh], 1.0, 61, None);
            let vfull = Tensor::rand(&[h, cap, dh], 1.0, 62, None);
            // carve the contiguous cache into pool-style blocks
            let k_blocks: Vec<Tensor> =
                (0..3).map(|bi| kfull.slice_axis(1, bi * bt, bt).to_contiguous(None)).collect();
            let v_blocks: Vec<Tensor> =
                (0..3).map(|bi| vfull.slice_axis(1, bi * bt, bt).to_contiguous(None)).collect();
            let q = Tensor::rand(&[h, 1, dh], 1.0, 63, None);
            for len in [1usize, bt - 1, bt, bt + 1, 63.min(cap), 64.min(cap), 65.min(cap), cap] {
                let kc = kfull.slice_axis(1, 0, len).to_contiguous(None);
                let vc = vfull.slice_axis(1, 0, len).to_contiguous(None);
                let want = incremental_attention(&q, &kc, &vc, 0.4, None);
                let got = paged_attention(&q, &k_blocks, &v_blocks, len, 0.4, None);
                let a: Vec<u32> = want.to_vec_f32().iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = got.to_vec_f32().iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "bt={bt} len={len} diverged");
            }
        }
    }

    /// Bytes past `len` in the tail block must be unobservable.
    #[test]
    fn paged_attention_ignores_tail_block_bytes() {
        let (h, bt, dh, len) = (2usize, 16usize, 4usize, 21usize);
        let mk = |poison: bool| -> Vec<Tensor> {
            (0..2usize)
                .map(|bi| {
                    let mut v = Tensor::rand(&[h, bt, dh], 1.0, 70 + bi as u64, None).to_vec_f32();
                    if poison && bi == 1 {
                        // rows >= len % bt of the tail block
                        for hi in 0..h {
                            for r in (len - bt)..bt {
                                for d in 0..dh {
                                    v[hi * bt * dh + r * dh + d] = f32::NAN;
                                }
                            }
                        }
                    }
                    Tensor::from_f32(v, &[h, bt, dh], None)
                })
                .collect()
        };
        let q = Tensor::rand(&[h, 1, dh], 1.0, 77, None);
        let clean = mk(false);
        let dirty = mk(true);
        let a = paged_attention(&q, &clean, &clean, len, 0.5, None).to_vec_f32();
        let b = paged_attention(&q, &dirty, &dirty, len, 0.5, None).to_vec_f32();
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb);
    }

    /// Each column of the multi-query paged kernel must be bitwise
    /// identical to the single-request paged kernel over the same block
    /// table — mixed lengths, mixed table sizes, tails crossing both
    /// block_tokens and KV_BLOCK boundaries.
    #[test]
    fn paged_attention_batched_matches_per_request_bitwise() {
        let (h, dh, bt) = (2usize, 8usize, 16usize);
        let lens = [1usize, 21, 48, 33]; // ragged, unsorted
        let n = lens.len();
        let q = Tensor::rand(&[h, n, dh], 1.0, 81, None);
        let k_tables: Vec<Vec<Tensor>> = (0..n)
            .map(|r| {
                let nblk = lens[r].div_ceil(bt);
                (0..nblk)
                    .map(|bi| Tensor::rand(&[h, bt, dh], 1.0, (90 + 10 * r + bi) as u64, None))
                    .collect()
            })
            .collect();
        let v_tables: Vec<Vec<Tensor>> = (0..n)
            .map(|r| {
                let nblk = lens[r].div_ceil(bt);
                (0..nblk)
                    .map(|bi| Tensor::rand(&[h, bt, dh], 1.0, (900 + 10 * r + bi) as u64, None))
                    .collect()
            })
            .collect();
        let got = paged_attention_batched(&q, &k_tables, &v_tables, &lens, 0.4, None);
        assert_eq!(got.shape(), &[h, n, dh]);
        for r in 0..n {
            let qr = q.slice_axis(1, r, 1).to_contiguous(None);
            let want = paged_attention(&qr, &k_tables[r], &v_tables[r], lens[r], 0.4, None);
            let a: Vec<u32> = want.to_vec_f32().iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = got
                .slice_axis(1, r, 1)
                .to_vec_f32()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(a, b, "request {r} (len {}) diverged", lens[r]);
        }
    }

    #[test]
    fn incremental_into_is_fused_into() {
        // The named decode entry point must be the same core.
        let q = Tensor::rand(&[2, 8], 1.0, 51, None);
        let k = Tensor::rand(&[70, 8], 1.0, 52, None);
        let v = Tensor::rand(&[70, 8], 1.0, 53, None);
        let mut a = vec![0.0f32; 2 * 8];
        let mut b = vec![0.0f32; 2 * 8];
        let sa = incremental_attention_into(&q, &k, &v, 0.7, &mut a, None);
        let sb = fused_attention_into(&q, &k, &v, 0.7, &mut b, None);
        assert_eq!(sa, sb);
        let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(ab, bb);
    }
}
