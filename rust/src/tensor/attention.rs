//! Fused memory-efficient attention (Rabe & Staats 2022).
//!
//! `softmax(q·kᵀ·scale)·v` computed by streaming over key/value blocks with
//! a running max and denominator, so the `[s_q, s_kv]` score matrix is
//! never materialized — peak workspace is `O(s_q·(d + B))` instead of
//! `O(s_q·s_kv)`. This is the "fused attention kernel" baseline of the
//! paper's Figure 6 (and the CPU twin of the L1 Pallas kernel in
//! `python/compile/kernels/attention.py`).

use super::{broadcast_shapes, MemoryTracker, Tensor};
use crate::util::pool;

/// Key/value block length for the streaming pass.
pub const KV_BLOCK: usize = 64;

/// Core of [`fused_attention`]: streams into `out` (length batch·sq·dv),
/// returning the output shape. Broadcast/contiguity materialization of
/// q/k/v remains transient workspace on `tracker`; the per-row running
/// max/denominator/score scratch is untracked worker-local state.
pub fn fused_attention_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert!(q.rank() >= 2);
    let rank = q.rank();
    let (sq, d) = (q.shape()[rank - 2], q.shape()[rank - 1]);
    let skv = k.shape()[k.rank() - 2];
    assert_eq!(k.shape()[k.rank() - 1], d, "k head dim");
    assert_eq!(v.shape()[v.rank() - 2], skv, "v rows");
    let dv = v.shape()[v.rank() - 1];

    let batch_shape = broadcast_shapes(
        &broadcast_shapes(&q.shape()[..rank - 2], &k.shape()[..k.rank() - 2]),
        &v.shape()[..v.rank() - 2],
    );
    let batch: usize = batch_shape.iter().product::<usize>().max(1);

    let mut qs = batch_shape.clone();
    qs.extend_from_slice(&[sq, d]);
    let mut ks = batch_shape.clone();
    ks.extend_from_slice(&[skv, d]);
    let mut vs = batch_shape.clone();
    vs.extend_from_slice(&[skv, dv]);
    let qc = q.broadcast_to(&qs).to_contiguous(tracker.clone());
    let kc = k.broadcast_to(&ks).to_contiguous(tracker.clone());
    let vc = v.broadcast_to(&vs).to_contiguous(tracker);
    let qv = qc.f32_contiguous();
    let kv = kc.f32_contiguous();
    let vv = vc.f32_contiguous();

    assert_eq!(out.len(), batch * sq * dv, "fused_attention_into length");
    // Every query row's online-softmax stream is independent of every
    // other row, so rows partition over the pool *within* each batch
    // element; each worker carries its own running max/denominator and
    // score scratch. The kv-block order per row is untouched, so results
    // are bitwise identical to the serial stream at any width.
    // Per-batch-element work: each par_rows call below covers one batch
    // element, so the inline-threshold decision must not be inflated by
    // the batch count.
    let work = sq * skv * (d + dv);
    for bi in 0..batch {
        let qm = &qv[bi * sq * d..(bi + 1) * sq * d];
        let km = &kv[bi * skv * d..(bi + 1) * skv * d];
        let vm = &vv[bi * skv * dv..(bi + 1) * skv * dv];
        let om = &mut out[bi * sq * dv..(bi + 1) * sq * dv];
        pool::par_rows(om, sq, dv, work, |i0, i1, om_slab| {
            let rows = i1 - i0;
            let mut m = vec![f32::NEG_INFINITY; rows];
            let mut l = vec![0.0f32; rows];
            let mut scores = vec![0.0f32; rows * KV_BLOCK];

            let mut blk = 0usize;
            while blk < skv {
                let bk = KV_BLOCK.min(skv - blk);
                // scores = q @ k_blk^T * scale
                for i in 0..rows {
                    let qr = &qm[(i0 + i) * d..(i0 + i + 1) * d];
                    for j in 0..bk {
                        let kr = &km[(blk + j) * d..(blk + j + 1) * d];
                        let mut acc = 0.0f32;
                        for p in 0..d {
                            acc += qr[p] * kr[p];
                        }
                        scores[i * bk + j] = acc * scale;
                    }
                }
                // online softmax update
                for i in 0..rows {
                    let row = &scores[i * bk..i * bk + bk];
                    let blk_max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let new_m = m[i].max(blk_max);
                    let correction = if m[i].is_finite() { (m[i] - new_m).exp() } else { 0.0 };
                    // rescale accumulated output and denominator
                    if correction != 1.0 {
                        for p in 0..dv {
                            om_slab[i * dv + p] *= correction;
                        }
                        l[i] *= correction;
                    }
                    for j in 0..bk {
                        let e = (row[j] - new_m).exp();
                        l[i] += e;
                        let vr = &vm[(blk + j) * dv..(blk + j + 1) * dv];
                        for p in 0..dv {
                            om_slab[i * dv + p] += e * vr[p];
                        }
                    }
                    m[i] = new_m;
                }
                blk += bk;
            }
            // normalize
            for i in 0..rows {
                let inv = 1.0 / l[i];
                for p in 0..dv {
                    om_slab[i * dv + p] *= inv;
                }
            }
        });
    }

    let mut out_shape = batch_shape;
    out_shape.extend_from_slice(&[sq, dv]);
    out_shape
}

/// Batched fused attention. `q: [..b, sq, d]`, `k,v: [..b, skv, d]`.
pub fn fused_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    scale: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let rank = q.rank();
    let (sq, dv) = (q.shape()[rank - 2], v.shape()[v.rank() - 1]);
    let batch: usize = broadcast_shapes(
        &broadcast_shapes(&q.shape()[..rank - 2], &k.shape()[..k.rank() - 2]),
        &v.shape()[..v.rank() - 2],
    )
    .iter()
    .product::<usize>()
    .max(1);
    let mut out = vec![0.0f32; batch * sq * dv];
    let out_shape = fused_attention_into(q, k, v, scale, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul;
    use crate::tensor::reduce::softmax;

    /// Dense reference: softmax(q k^T scale) v.
    fn dense_attention(q: &Tensor, k: &Tensor, v: &Tensor, scale: f32) -> Tensor {
        let rank = k.rank();
        let mut perm: Vec<usize> = (0..rank).collect();
        perm.swap(rank - 1, rank - 2);
        let kt = k.permute(&perm);
        let scores = matmul(q, &kt, None);
        let scaled = crate::tensor::ops::binary_scalar(
            crate::tensor::ops::BinaryOp::Mul,
            &scores,
            scale,
            None,
        );
        let probs = softmax(&scaled, scaled.rank() - 1, None);
        matmul(&probs, v, None)
    }

    #[test]
    fn matches_dense_reference_2d() {
        for &(sq, skv, d) in &[(16, 16, 8), (33, 100, 4), (8, 200, 16)] {
            let q = Tensor::rand(&[sq, d], 1.0, 1, None);
            let k = Tensor::rand(&[skv, d], 1.0, 2, None);
            let v = Tensor::rand(&[skv, d], 1.0, 3, None);
            let got = fused_attention(&q, &k, &v, 0.3, None);
            let want = dense_attention(&q, &k, &v, 0.3);
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "({sq},{skv},{d}): {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn matches_dense_reference_batched() {
        let q = Tensor::rand(&[4, 32, 8], 1.0, 5, None);
        let k = Tensor::rand(&[4, 96, 8], 1.0, 6, None);
        let v = Tensor::rand(&[4, 96, 8], 1.0, 7, None);
        let got = fused_attention(&q, &k, &v, 0.35, None);
        let want = dense_attention(&q, &k, &v, 0.35);
        assert_eq!(got.shape(), &[4, 32, 8]);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn single_block_path() {
        // skv < KV_BLOCK exercises the tail-only path
        let q = Tensor::rand(&[5, 4], 1.0, 8, None);
        let k = Tensor::rand(&[7, 4], 1.0, 9, None);
        let v = Tensor::rand(&[7, 4], 1.0, 10, None);
        let got = fused_attention(&q, &k, &v, 1.0, None);
        let want = dense_attention(&q, &k, &v, 1.0);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn numerically_stable_large_logits() {
        let q = Tensor::rand(&[4, 8], 30.0, 11, None);
        let k = Tensor::rand(&[128, 8], 30.0, 12, None);
        let v = Tensor::rand(&[128, 8], 1.0, 13, None);
        let got = fused_attention(&q, &k, &v, 1.0, None);
        assert!(got.to_vec_f32().iter().all(|x| x.is_finite()));
        let want = dense_attention(&q, &k, &v, 1.0);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }
}
