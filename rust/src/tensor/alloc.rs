//! Instrumented allocation tracking.
//!
//! Every tensor buffer is registered with a [`MemoryTracker`]. The tracker
//! maintains the number of live activation bytes and its high-water mark,
//! which is the quantity AutoChunk optimizes (the CUDA-allocator peak on the
//! paper's A100 testbed; see DESIGN.md §5 for the substitution argument).
//!
//! Buffers deregister on `Drop`, so peak tracking falls out of normal Rust
//! ownership: the executor drops a value when its last consumer has run, the
//! buffer frees, and `current` decreases.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared counters behind a [`MemoryTracker`] handle.
#[derive(Debug, Default)]
struct TrackerInner {
    /// Live tracked bytes right now.
    current: AtomicUsize,
    /// High-water mark of `current` since the last [`MemoryTracker::reset_peak`].
    peak: AtomicUsize,
    /// Total number of allocations ever registered (profiling signal).
    allocs: AtomicUsize,
    /// Total bytes ever allocated (profiling signal).
    total_allocated: AtomicUsize,
}

/// Cloneable handle on a set of live/peak byte counters.
///
/// A tracker is *optional* per buffer: weights and test fixtures are usually
/// allocated against `MemoryTracker::untracked()` style `None`, while the
/// executor allocates every intermediate against the run's tracker so that
/// the peak reflects activation memory only — mirroring the paper's
/// definition (Eq. 1: inputs + outputs + intermediates, not parameters).
#[derive(Clone, Debug, Default)]
pub struct MemoryTracker {
    inner: Arc<TrackerInner>,
}

impl MemoryTracker {
    /// New tracker with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Live tracked bytes.
    pub fn current(&self) -> usize {
        self.inner.current.load(Ordering::Relaxed)
    }

    /// High-water mark of live bytes since construction or the last reset.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Number of allocations registered.
    pub fn alloc_count(&self) -> usize {
        self.inner.allocs.load(Ordering::Relaxed)
    }

    /// Total bytes ever allocated (cumulative, never decremented).
    pub fn total_allocated(&self) -> usize {
        self.inner.total_allocated.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live level (not to zero: anything still
    /// alive is still occupying memory).
    pub fn reset_peak(&self) {
        let cur = self.current();
        self.inner.peak.store(cur, Ordering::Relaxed);
    }

    pub(crate) fn on_alloc(&self, bytes: usize) {
        let prev = self.inner.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        self.inner.allocs.fetch_add(1, Ordering::Relaxed);
        self.inner.total_allocated.fetch_add(bytes, Ordering::Relaxed);
        // Racy max update is fine: worst case we retry.
        let mut peak = self.inner.peak.load(Ordering::Relaxed);
        while now > peak {
            match self.inner.peak.compare_exchange_weak(
                peak,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    pub(crate) fn on_free(&self, bytes: usize) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Raw storage for tensor elements.
///
/// Compute is f32 (plus i32 for token ids / gather indices). Other logical
/// dtypes scale byte accounting via [`crate::tensor::DType::size_of`].
#[derive(Debug)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }
}

/// A tracked, reference-counted buffer. Dropping the last reference
/// deregisters the bytes from the tracker.
#[derive(Debug)]
pub struct Buffer {
    pub(crate) storage: Storage,
    tracker: Option<MemoryTracker>,
    bytes: usize,
}

impl Buffer {
    /// Allocate a buffer, registering `storage.byte_len()` with `tracker`.
    pub fn new(storage: Storage, tracker: Option<MemoryTracker>) -> Arc<Self> {
        let bytes = storage.byte_len();
        if let Some(t) = &tracker {
            t.on_alloc(bytes);
        }
        Arc::new(Buffer {
            storage,
            tracker,
            bytes,
        })
    }

    pub fn f32(&self) -> &[f32] {
        match &self.storage {
            Storage::F32(v) => v,
            Storage::I32(_) => panic!("buffer holds i32, expected f32"),
        }
    }

    pub fn i32(&self) -> &[i32] {
        match &self.storage {
            Storage::I32(v) => v,
            Storage::F32(_) => panic!("buffer holds f32, expected i32"),
        }
    }
}

impl Drop for Buffer {
    fn drop(&mut self) {
        if let Some(t) = &self.tracker {
            t.on_free(self.bytes);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_counts_alloc_and_free() {
        let t = MemoryTracker::new();
        let b1 = Buffer::new(Storage::F32(vec![0.0; 256]), Some(t.clone()));
        assert_eq!(t.current(), 1024);
        assert_eq!(t.peak(), 1024);
        let b2 = Buffer::new(Storage::F32(vec![0.0; 128]), Some(t.clone()));
        assert_eq!(t.current(), 1024 + 512);
        assert_eq!(t.peak(), 1536);
        drop(b1);
        assert_eq!(t.current(), 512);
        assert_eq!(t.peak(), 1536, "peak is a high-water mark");
        drop(b2);
        assert_eq!(t.current(), 0);
        assert_eq!(t.alloc_count(), 2);
        assert_eq!(t.total_allocated(), 1536);
    }

    #[test]
    fn reset_peak_resets_to_current() {
        let t = MemoryTracker::new();
        let b1 = Buffer::new(Storage::F32(vec![0.0; 100]), Some(t.clone()));
        {
            let _b2 = Buffer::new(Storage::F32(vec![0.0; 1000]), Some(t.clone()));
        }
        assert_eq!(t.peak(), 4400);
        t.reset_peak();
        assert_eq!(t.peak(), 400);
        drop(b1);
    }

    #[test]
    fn untracked_buffer_does_not_count() {
        let t = MemoryTracker::new();
        let _b = Buffer::new(Storage::F32(vec![0.0; 64]), None);
        assert_eq!(t.current(), 0);
    }

    #[test]
    fn shared_buffer_freed_once() {
        let t = MemoryTracker::new();
        let b = Buffer::new(Storage::F32(vec![0.0; 10]), Some(t.clone()));
        let b2 = Arc::clone(&b);
        drop(b);
        assert_eq!(t.current(), 40, "still one live reference");
        drop(b2);
        assert_eq!(t.current(), 0);
    }
}
