//! Instrumented allocation tracking and the planned-allocation arena.
//!
//! Every tensor buffer is registered with a [`MemoryTracker`]. The tracker
//! maintains the number of live activation bytes and its high-water mark,
//! which is the quantity AutoChunk optimizes (the CUDA-allocator peak on the
//! paper's A100 testbed; see DESIGN.md §5 for the substitution argument).
//!
//! Buffers deregister on `Drop`, so peak tracking falls out of normal Rust
//! ownership: the executor drops a value when its last consumer has run, the
//! buffer frees, and `current` decreases.
//!
//! The [`Arena`] is the runtime half of the static memory planner
//! (`passes::memplan`, DESIGN.md §12): the planner assigns every
//! materialized intermediate an offset range (*slot*) in a single arena;
//! at execution time the arena hands out recycled backing storage per slot
//! and accounts live bytes at the *planned* slot size, so its high-water
//! mark is exactly the planner's `planned_peak_bytes` — and after the
//! first execution the hot path performs no per-op allocation at all
//! (slot storage is cached in an [`ArenaStore`] and reused).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Shared counters behind a [`MemoryTracker`] handle.
#[derive(Debug, Default)]
struct TrackerInner {
    /// Live tracked bytes right now.
    current: AtomicUsize,
    /// High-water mark of `current` since the last [`MemoryTracker::reset_peak`].
    peak: AtomicUsize,
    /// Total number of allocations ever registered (profiling signal).
    allocs: AtomicUsize,
    /// Total bytes ever allocated (profiling signal).
    total_allocated: AtomicUsize,
}

/// Cloneable handle on a set of live/peak byte counters.
///
/// A tracker is *optional* per buffer: weights and test fixtures are usually
/// allocated against `MemoryTracker::untracked()` style `None`, while the
/// executor allocates every intermediate against the run's tracker so that
/// the peak reflects activation memory only — mirroring the paper's
/// definition (Eq. 1: inputs + outputs + intermediates, not parameters).
#[derive(Clone, Debug, Default)]
pub struct MemoryTracker {
    inner: Arc<TrackerInner>,
}

impl MemoryTracker {
    /// New tracker with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Live tracked bytes.
    pub fn current(&self) -> usize {
        self.inner.current.load(Ordering::Relaxed)
    }

    /// High-water mark of live bytes since construction or the last reset.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Number of allocations registered.
    pub fn alloc_count(&self) -> usize {
        self.inner.allocs.load(Ordering::Relaxed)
    }

    /// Total bytes ever allocated (cumulative, never decremented).
    pub fn total_allocated(&self) -> usize {
        self.inner.total_allocated.load(Ordering::Relaxed)
    }

    /// Reset the peak to the current live level (not to zero: anything still
    /// alive is still occupying memory).
    pub fn reset_peak(&self) {
        let cur = self.current();
        self.inner.peak.store(cur, Ordering::Relaxed);
    }

    pub(crate) fn on_alloc(&self, bytes: usize) {
        let prev = self.inner.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        self.inner.allocs.fetch_add(1, Ordering::Relaxed);
        self.inner.total_allocated.fetch_add(bytes, Ordering::Relaxed);
        // Racy max update is fine: worst case we retry.
        let mut peak = self.inner.peak.load(Ordering::Relaxed);
        while now > peak {
            match self.inner.peak.compare_exchange_weak(
                peak,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    pub(crate) fn on_free(&self, bytes: usize) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Register arena-slot bytes as live without counting allocator
    /// traffic: the backing storage is recycled slot storage, not a fresh
    /// allocation, so `allocs`/`total_allocated` must not move — they are
    /// the allocator-churn signal the arena exists to eliminate.
    pub(crate) fn on_bind(&self, bytes: usize) {
        let prev = self.inner.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        let mut peak = self.inner.peak.load(Ordering::Relaxed);
        while now > peak {
            match self.inner.peak.compare_exchange_weak(
                peak,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    pub(crate) fn on_unbind(&self, bytes: usize) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Shared counters behind a [`SpillStore`] handle.
#[derive(Debug, Default)]
struct SpillInner {
    /// Bytes currently parked in the slow tier.
    current: AtomicUsize,
    /// High-water mark of `current`.
    peak: AtomicUsize,
    /// Total bytes ever moved fast → slow (cumulative).
    bytes_out: AtomicUsize,
    /// Total bytes ever moved slow → fast (cumulative).
    bytes_in: AtomicUsize,
    /// Spill events (fast → slow transfers).
    spills: AtomicUsize,
    /// Restore events (slow → fast transfers).
    restores: AtomicUsize,
}

/// Byte accounting for the simulated **slow tier** (DESIGN.md §18): the
/// destination of planner-placed activation spills and of cold paged KV
/// blocks evicted under pool pressure. The store holds no storage itself —
/// spilled payloads live with their owner (the arena executor's stash, the
/// cache manager's [`crate::tensor::kvpage`] spill tables); this is the
/// shared ledger that makes "bytes parked off the fast tier" a first-class,
/// exactly-accounted quantity.
///
/// Deliberately *not* a [`MemoryTracker`]: the run tracker's `current`
/// must keep meaning fast-tier bytes only (the invariant auditor pins
/// `tracker.current() == resident KV` between waves, and `measured_peak`
/// is the fast-tier peak the planner bounds).
#[derive(Clone, Debug, Default)]
pub struct SpillStore {
    inner: Arc<SpillInner>,
}

impl SpillStore {
    pub fn new() -> SpillStore {
        SpillStore::default()
    }

    /// Bytes parked in the slow tier right now.
    pub fn current(&self) -> usize {
        self.inner.current.load(Ordering::Relaxed)
    }

    /// High-water mark of parked bytes.
    pub fn peak(&self) -> usize {
        self.inner.peak.load(Ordering::Relaxed)
    }

    /// Cumulative bytes transferred fast → slow.
    pub fn bytes_out(&self) -> usize {
        self.inner.bytes_out.load(Ordering::Relaxed)
    }

    /// Cumulative bytes transferred slow → fast.
    pub fn bytes_in(&self) -> usize {
        self.inner.bytes_in.load(Ordering::Relaxed)
    }

    /// Spill events so far.
    pub fn spills(&self) -> usize {
        self.inner.spills.load(Ordering::Relaxed)
    }

    /// Restore events so far.
    pub fn restores(&self) -> usize {
        self.inner.restores.load(Ordering::Relaxed)
    }

    /// Account `bytes` moving fast → slow.
    pub fn on_spill(&self, bytes: usize) {
        let prev = self.inner.current.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        self.inner.bytes_out.fetch_add(bytes, Ordering::Relaxed);
        self.inner.spills.fetch_add(1, Ordering::Relaxed);
        let mut peak = self.inner.peak.load(Ordering::Relaxed);
        while now > peak {
            match self.inner.peak.compare_exchange_weak(
                peak,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(p) => peak = p,
            }
        }
    }

    /// Account `bytes` moving slow → fast.
    pub fn on_restore(&self, bytes: usize) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
        self.inner.bytes_in.fetch_add(bytes, Ordering::Relaxed);
        self.inner.restores.fetch_add(1, Ordering::Relaxed);
    }

    /// Account `bytes` leaving the slow tier without a restore (the owner
    /// discarded the payload — an evicted generation, a recompute-placed
    /// value whose stash never existed has nothing to discard).
    pub fn on_discard(&self, bytes: usize) {
        self.inner.current.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Raw storage for tensor elements.
///
/// Compute is f32 (plus i32 for token ids / gather indices). Other logical
/// dtypes scale byte accounting via [`crate::tensor::DType::size_of`].
#[derive(Debug)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    pub fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }
}

/// One planned allocation: a byte range inside the arena. Produced by the
/// static memory planner's best-fit interval assignment; two values whose
/// live ranges do not overlap may be assigned the same slot (buffer reuse).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotSpec {
    /// Byte offset of the slot inside the arena.
    pub offset: usize,
    /// Planned capacity in bytes. Accounting always charges this full
    /// amount, even when a short chunk tail writes fewer bytes — a real
    /// slab reserves the slot regardless.
    pub bytes: usize,
}

/// Cached backing storage per slot, shared across executions so a plan
/// re-run (the serving hot path) performs zero fresh allocations. Safe to
/// share between concurrent executions of the same plan: a concurrent run
/// finding a slot's cache empty simply allocates fresh storage.
#[derive(Clone, Debug)]
pub struct ArenaStore {
    inner: Arc<StoreInner>,
}

#[derive(Debug)]
struct StoreInner {
    cache: Vec<Mutex<Vec<Storage>>>,
    /// Fresh backing allocations performed (cold misses).
    fresh: AtomicUsize,
    /// Acquires served from the cache (the churn the arena removes).
    reused: AtomicUsize,
}

impl ArenaStore {
    pub fn new(n_slots: usize) -> ArenaStore {
        ArenaStore {
            inner: Arc::new(StoreInner {
                cache: (0..n_slots).map(|_| Mutex::new(Vec::new())).collect(),
                fresh: AtomicUsize::new(0),
                reused: AtomicUsize::new(0),
            }),
        }
    }

    pub fn n_slots(&self) -> usize {
        self.inner.cache.len()
    }

    /// Fresh backing allocations performed so far (across all runs).
    pub fn fresh_allocs(&self) -> usize {
        self.inner.fresh.load(Ordering::Relaxed)
    }

    /// Acquires served by recycled storage so far.
    pub fn reuses(&self) -> usize {
        self.inner.reused.load(Ordering::Relaxed)
    }
}

/// Runtime view of one execution over a planned arena: per-run live/peak
/// accounting (at planned slot sizes) over an [`ArenaStore`]'s recycled
/// storage. The high-water mark of a run that follows the plan equals the
/// planner's `planned_peak_bytes` exactly — the property
/// `rust/tests/memplan_exact.rs` pins.
#[derive(Clone, Debug)]
pub struct Arena {
    inner: Arc<ArenaInner>,
}

#[derive(Debug)]
struct ArenaInner {
    slots: Vec<SlotSpec>,
    store: ArenaStore,
    live: AtomicUsize,
    high: AtomicUsize,
    acquires: AtomicUsize,
    /// Per-run fresh-allocation count — unlike the store's monotonic
    /// counters, concurrent runs sharing a store do not see each other's
    /// traffic here.
    fresh: AtomicUsize,
    /// Per-run cache-served acquire count.
    reused: AtomicUsize,
}

impl Arena {
    /// Arena over `slots` with a private (fresh) storage cache.
    pub fn new(slots: Vec<SlotSpec>) -> Arena {
        let store = ArenaStore::new(slots.len());
        Arena::with_store(slots, store)
    }

    /// Arena over `slots` backed by a shared store (plan-cache hot path).
    /// `store.n_slots()` must match `slots.len()`.
    pub fn with_store(slots: Vec<SlotSpec>, store: ArenaStore) -> Arena {
        assert_eq!(store.n_slots(), slots.len(), "store/slot arity");
        Arena {
            inner: Arc::new(ArenaInner {
                slots,
                store,
                live: AtomicUsize::new(0),
                high: AtomicUsize::new(0),
                acquires: AtomicUsize::new(0),
                fresh: AtomicUsize::new(0),
                reused: AtomicUsize::new(0),
            }),
        }
    }

    pub fn slot_count(&self) -> usize {
        self.inner.slots.len()
    }

    pub fn slot_bytes(&self, slot: usize) -> usize {
        self.inner.slots[slot].bytes
    }

    /// Total byte footprint a contiguous slab for this plan would reserve
    /// (max `offset + bytes` over slots).
    pub fn footprint(&self) -> usize {
        self.inner
            .slots
            .iter()
            .map(|s| s.offset + s.bytes)
            .max()
            .unwrap_or(0)
    }

    /// Live planned bytes right now.
    pub fn live(&self) -> usize {
        self.inner.live.load(Ordering::Relaxed)
    }

    /// High-water mark of live planned bytes over this run.
    pub fn high_water(&self) -> usize {
        self.inner.high.load(Ordering::Relaxed)
    }

    pub fn acquires(&self) -> usize {
        self.inner.acquires.load(Ordering::Relaxed)
    }

    /// Fresh backing allocations performed by *this run* (cold misses).
    pub fn fresh_allocs(&self) -> usize {
        self.inner.fresh.load(Ordering::Relaxed)
    }

    /// Acquires served from the cache by *this run*.
    pub fn reuses(&self) -> usize {
        self.inner.reused.load(Ordering::Relaxed)
    }

    pub fn store(&self) -> &ArenaStore {
        &self.inner.store
    }

    fn count_acquire(&self, slot: usize) {
        let bytes = self.inner.slots[slot].bytes;
        self.inner.acquires.fetch_add(1, Ordering::Relaxed);
        let prev = self.inner.live.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        let mut high = self.inner.high.load(Ordering::Relaxed);
        while now > high {
            match self.inner.high.compare_exchange_weak(
                high,
                now,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(h) => high = h,
            }
        }
    }

    /// Take zeroed f32 storage of `len` elements for `slot`, charging the
    /// slot's planned bytes. `len * 4` must not exceed the planned size
    /// (short chunk tails write less; nothing writes more).
    pub fn acquire_f32(&self, slot: usize, len: usize) -> Vec<f32> {
        assert!(
            len * 4 <= self.inner.slots[slot].bytes,
            "slot {slot} acquire {} bytes exceeds planned {}",
            len * 4,
            self.inner.slots[slot].bytes
        );
        self.count_acquire(slot);
        let cached = self.inner.store.inner.cache[slot].lock().unwrap().pop();
        match cached {
            Some(Storage::F32(mut v)) => {
                self.inner.store.inner.reused.fetch_add(1, Ordering::Relaxed);
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v.resize(len, 0.0);
                v
            }
            other => {
                // dtype-mismatched cached storage is simply dropped
                drop(other);
                self.inner.store.inner.fresh.fetch_add(1, Ordering::Relaxed);
                self.inner.fresh.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; len]
            }
        }
    }

    /// As [`Arena::acquire_f32`] for i32 storage.
    pub fn acquire_i32(&self, slot: usize, len: usize) -> Vec<i32> {
        assert!(
            len * 4 <= self.inner.slots[slot].bytes,
            "slot {slot} acquire {} bytes exceeds planned {}",
            len * 4,
            self.inner.slots[slot].bytes
        );
        self.count_acquire(slot);
        let cached = self.inner.store.inner.cache[slot].lock().unwrap().pop();
        match cached {
            Some(Storage::I32(mut v)) => {
                self.inner.store.inner.reused.fetch_add(1, Ordering::Relaxed);
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v.resize(len, 0);
                v
            }
            other => {
                drop(other);
                self.inner.store.inner.fresh.fetch_add(1, Ordering::Relaxed);
                self.inner.fresh.fetch_add(1, Ordering::Relaxed);
                vec![0i32; len]
            }
        }
    }

    /// Return a slot's storage to the cache and release its planned bytes.
    pub(crate) fn release(&self, slot: usize, storage: Storage) {
        let bytes = self.inner.slots[slot].bytes;
        self.inner.live.fetch_sub(bytes, Ordering::Relaxed);
        self.inner.store.inner.cache[slot].lock().unwrap().push(storage);
    }
}

/// A tracked, reference-counted buffer. Dropping the last reference
/// deregisters the bytes from the tracker (and, for arena-backed buffers,
/// returns the storage to its slot).
#[derive(Debug)]
pub struct Buffer {
    pub(crate) storage: Storage,
    tracker: Option<MemoryTracker>,
    bytes: usize,
    /// Arena backing: (arena, slot). Set for planner-allocated buffers;
    /// `bytes` then holds the *planned* slot size, and the tracker charge
    /// went through `on_bind` rather than `on_alloc`.
    arena: Option<(Arena, usize)>,
}

impl Buffer {
    /// Allocate a buffer, registering `storage.byte_len()` with `tracker`.
    pub fn new(storage: Storage, tracker: Option<MemoryTracker>) -> Arc<Self> {
        let bytes = storage.byte_len();
        if let Some(t) = &tracker {
            t.on_alloc(bytes);
        }
        Arc::new(Buffer {
            storage,
            tracker,
            bytes,
            arena: None,
        })
    }

    /// Wrap storage acquired from `arena` slot `slot`. The arena already
    /// counted the acquire; the tracker is charged the planned slot bytes
    /// via `on_bind` (live/peak only — no allocator traffic).
    pub(crate) fn new_arena(
        storage: Storage,
        arena: Arena,
        slot: usize,
        tracker: Option<MemoryTracker>,
    ) -> Arc<Self> {
        let bytes = arena.slot_bytes(slot);
        if let Some(t) = &tracker {
            t.on_bind(bytes);
        }
        Arc::new(Buffer {
            storage,
            tracker,
            bytes,
            arena: Some((arena, slot)),
        })
    }

    /// Re-wrap storage taken from a dying arena buffer (in-place compute):
    /// no counters move — the original acquire/bind stays live and this
    /// buffer's drop performs the single matching release/unbind.
    pub(crate) fn adopt_arena(
        storage: Storage,
        arena: Arena,
        slot: usize,
        tracker: Option<MemoryTracker>,
    ) -> Arc<Self> {
        let bytes = arena.slot_bytes(slot);
        Arc::new(Buffer {
            storage,
            tracker,
            bytes,
            arena: Some((arena, slot)),
        })
    }

    /// Disarm this buffer and hand out its parts for in-place reuse. The
    /// subsequent `Drop` of the emptied shell is a no-op.
    #[allow(clippy::type_complexity)]
    pub(crate) fn take_for_inplace(
        mut self,
    ) -> (Storage, Option<(Arena, usize)>, Option<MemoryTracker>) {
        let storage = std::mem::replace(&mut self.storage, Storage::F32(Vec::new()));
        let arena = self.arena.take();
        let tracker = self.tracker.take();
        (storage, arena, tracker)
    }

    /// True if this buffer is backed by the given arena slot.
    pub(crate) fn arena_slot(&self) -> Option<usize> {
        self.arena.as_ref().map(|&(_, s)| s)
    }

    pub fn f32(&self) -> &[f32] {
        match &self.storage {
            Storage::F32(v) => v,
            Storage::I32(_) => panic!("buffer holds i32, expected f32"),
        }
    }

    pub fn i32(&self) -> &[i32] {
        match &self.storage {
            Storage::I32(v) => v,
            Storage::F32(_) => panic!("buffer holds f32, expected i32"),
        }
    }
}

impl Drop for Buffer {
    fn drop(&mut self) {
        match self.arena.take() {
            Some((arena, slot)) => {
                if let Some(t) = &self.tracker {
                    t.on_unbind(self.bytes);
                }
                let storage = std::mem::replace(&mut self.storage, Storage::F32(Vec::new()));
                arena.release(slot, storage);
            }
            None => {
                if let Some(t) = &self.tracker {
                    t.on_free(self.bytes);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_counts_alloc_and_free() {
        let t = MemoryTracker::new();
        let b1 = Buffer::new(Storage::F32(vec![0.0; 256]), Some(t.clone()));
        assert_eq!(t.current(), 1024);
        assert_eq!(t.peak(), 1024);
        let b2 = Buffer::new(Storage::F32(vec![0.0; 128]), Some(t.clone()));
        assert_eq!(t.current(), 1024 + 512);
        assert_eq!(t.peak(), 1536);
        drop(b1);
        assert_eq!(t.current(), 512);
        assert_eq!(t.peak(), 1536, "peak is a high-water mark");
        drop(b2);
        assert_eq!(t.current(), 0);
        assert_eq!(t.alloc_count(), 2);
        assert_eq!(t.total_allocated(), 1536);
    }

    #[test]
    fn reset_peak_resets_to_current() {
        let t = MemoryTracker::new();
        let b1 = Buffer::new(Storage::F32(vec![0.0; 100]), Some(t.clone()));
        {
            let _b2 = Buffer::new(Storage::F32(vec![0.0; 1000]), Some(t.clone()));
        }
        assert_eq!(t.peak(), 4400);
        t.reset_peak();
        assert_eq!(t.peak(), 400);
        drop(b1);
    }

    #[test]
    fn untracked_buffer_does_not_count() {
        let t = MemoryTracker::new();
        let _b = Buffer::new(Storage::F32(vec![0.0; 64]), None);
        assert_eq!(t.current(), 0);
    }

    #[test]
    fn arena_accounts_planned_bytes_and_recycles() {
        let arena = Arena::new(vec![
            SlotSpec { offset: 0, bytes: 64 },
            SlotSpec { offset: 64, bytes: 128 },
        ]);
        assert_eq!(arena.footprint(), 192);
        let v0 = arena.acquire_f32(0, 16);
        assert_eq!(v0.len(), 16);
        assert_eq!(arena.live(), 64);
        // short acquire still charges the planned size
        let v1 = arena.acquire_f32(1, 8);
        assert_eq!(arena.live(), 64 + 128);
        assert_eq!(arena.high_water(), 192);
        assert_eq!(arena.store().fresh_allocs(), 2);
        arena.release(0, Storage::F32(v0));
        arena.release(1, Storage::F32(v1));
        assert_eq!(arena.live(), 0);
        assert_eq!(arena.high_water(), 192, "high water is sticky");
        // second round comes from the cache
        let v0 = arena.acquire_f32(0, 16);
        assert!(v0.iter().all(|&x| x == 0.0), "recycled storage is zeroed");
        assert_eq!(arena.store().fresh_allocs(), 2);
        assert_eq!(arena.store().reuses(), 1);
        arena.release(0, Storage::F32(v0));
    }

    #[test]
    fn arena_buffer_binds_tracker_without_alloc_traffic() {
        let t = MemoryTracker::new();
        let arena = Arena::new(vec![SlotSpec { offset: 0, bytes: 400 }]);
        let v = arena.acquire_f32(0, 100);
        let b = Buffer::new_arena(Storage::F32(v), arena.clone(), 0, Some(t.clone()));
        assert_eq!(t.current(), 400);
        assert_eq!(t.peak(), 400);
        assert_eq!(t.alloc_count(), 0, "arena binds are not allocator traffic");
        assert_eq!(t.total_allocated(), 0);
        drop(b);
        assert_eq!(t.current(), 0);
        assert_eq!(arena.live(), 0, "drop returned the slot");
        // storage landed back in the cache
        let v = arena.acquire_f32(0, 100);
        assert_eq!(arena.store().reuses(), 1);
        arena.release(0, Storage::F32(v));
    }

    #[test]
    fn shared_arena_store_survives_runs() {
        let slots = vec![SlotSpec { offset: 0, bytes: 40 }];
        let store = ArenaStore::new(1);
        let run1 = Arena::with_store(slots.clone(), store.clone());
        let v = run1.acquire_f32(0, 10);
        run1.release(0, Storage::F32(v));
        let run2 = Arena::with_store(slots, store.clone());
        let v = run2.acquire_f32(0, 10);
        assert_eq!(store.fresh_allocs(), 1);
        assert_eq!(store.reuses(), 1);
        assert_eq!(run2.high_water(), 40);
        assert_eq!(run1.high_water(), 40, "runs account independently");
        run2.release(0, Storage::F32(v));
    }

    #[test]
    fn spill_store_accounts_exactly() {
        let s = SpillStore::new();
        s.on_spill(100);
        s.on_spill(50);
        assert_eq!(s.current(), 150);
        assert_eq!(s.peak(), 150);
        assert_eq!(s.bytes_out(), 150);
        assert_eq!(s.spills(), 2);
        s.on_restore(100);
        assert_eq!(s.current(), 50);
        assert_eq!(s.peak(), 150, "peak is a high-water mark");
        assert_eq!(s.bytes_in(), 100);
        assert_eq!(s.restores(), 1);
        s.on_discard(50);
        assert_eq!(s.current(), 0);
        assert_eq!(s.bytes_out(), 150, "discard moves no transfer bytes");
        // handles share counters
        let s2 = s.clone();
        s2.on_spill(8);
        assert_eq!(s.current(), 8);
    }

    #[test]
    fn shared_buffer_freed_once() {
        let t = MemoryTracker::new();
        let b = Buffer::new(Storage::F32(vec![0.0; 10]), Some(t.clone()));
        let b2 = Arc::clone(&b);
        drop(b);
        assert_eq!(t.current(), 40, "still one live reference");
        drop(b2);
        assert_eq!(t.current(), 0);
    }
}
