//! Elementwise binary/unary kernels with numpy-style broadcasting.
//!
//! Fast path: both operands contiguous with identical shapes → a single
//! vectorizable loop. Slow path: strided traversal via offset iterators.
//! The fast/slow gap is intentional and physical — it is what makes the
//! chunk-selection stride term meaningful on this substrate.

use super::{broadcast_shapes, MemoryTracker, Tensor};
use crate::util::pool;

/// Binary elementwise operator.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BinaryOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
}

impl BinaryOp {
    #[inline(always)]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            BinaryOp::Add => a + b,
            BinaryOp::Sub => a - b,
            BinaryOp::Mul => a * b,
            BinaryOp::Div => a / b,
            BinaryOp::Max => a.max(b),
            BinaryOp::Min => a.min(b),
            BinaryOp::Pow => a.powf(b),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BinaryOp::Add => "add",
            BinaryOp::Sub => "sub",
            BinaryOp::Mul => "mul",
            BinaryOp::Div => "div",
            BinaryOp::Max => "max",
            BinaryOp::Min => "min",
            BinaryOp::Pow => "pow",
        }
    }
}

/// Unary elementwise operator.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum UnaryOp {
    Neg,
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    Tanh,
    Sigmoid,
    Relu,
    Gelu,
    Silu,
    Abs,
}

impl UnaryOp {
    #[inline(always)]
    pub fn apply(self, x: f32) -> f32 {
        match self {
            UnaryOp::Neg => -x,
            UnaryOp::Exp => x.exp(),
            UnaryOp::Log => x.ln(),
            UnaryOp::Sqrt => x.sqrt(),
            UnaryOp::Rsqrt => 1.0 / x.sqrt(),
            UnaryOp::Tanh => x.tanh(),
            UnaryOp::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            UnaryOp::Relu => x.max(0.0),
            // tanh approximation of GELU, matching jax.nn.gelu default.
            UnaryOp::Gelu => {
                const C: f32 = 0.797_884_6; // sqrt(2/pi)
                0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
            }
            UnaryOp::Silu => x / (1.0 + (-x).exp()),
            UnaryOp::Abs => x.abs(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            UnaryOp::Neg => "neg",
            UnaryOp::Exp => "exp",
            UnaryOp::Log => "log",
            UnaryOp::Sqrt => "sqrt",
            UnaryOp::Rsqrt => "rsqrt",
            UnaryOp::Tanh => "tanh",
            UnaryOp::Sigmoid => "sigmoid",
            UnaryOp::Relu => "relu",
            UnaryOp::Gelu => "gelu",
            UnaryOp::Silu => "silu",
            UnaryOp::Abs => "abs",
        }
    }
}

/// Core of [`binary`]: computes `op(a, b)` with broadcasting into `out`
/// (row-major, length = numel of the broadcast shape) and returns that
/// shape. The arena executor calls this with planned slot storage; the
/// allocating wrapper with a fresh vec — results are bitwise identical.
pub fn binary_into(op: BinaryOp, a: &Tensor, b: &Tensor, out: &mut [f32]) -> Vec<usize> {
    let out_shape = broadcast_shapes(a.shape(), b.shape());
    let n = super::numel(&out_shape);
    assert_eq!(out.len(), n, "binary_into length mismatch");

    // Fast path: same shape, both contiguous. Monomorphized per-op loops
    // (so the compiler can vectorize) over disjoint output ranges.
    if a.shape() == out_shape.as_slice()
        && b.shape() == out_shape.as_slice()
        && a.is_contiguous()
        && b.is_contiguous()
    {
        let av = a.f32_contiguous();
        let bv = b.f32_contiguous();
        fn fill(out: &mut [f32], av: &[f32], bv: &[f32], f: impl Fn(f32, f32) -> f32 + Sync) {
            pool::par_rows(out, av.len(), 1, av.len(), |r0, _r1, slab| {
                for (j, o) in slab.iter_mut().enumerate() {
                    *o = f(av[r0 + j], bv[r0 + j]);
                }
            });
        }
        match op {
            BinaryOp::Add => fill(out, av, bv, |x, y| x + y),
            BinaryOp::Sub => fill(out, av, bv, |x, y| x - y),
            BinaryOp::Mul => fill(out, av, bv, |x, y| x * y),
            BinaryOp::Div => fill(out, av, bv, |x, y| x / y),
            BinaryOp::Max => fill(out, av, bv, f32::max),
            BinaryOp::Min => fill(out, av, bv, f32::min),
            BinaryOp::Pow => fill(out, av, bv, f32::powf),
        }
        return out_shape;
    }

    // Broadcast path: expand views then walk offsets in lockstep.
    let ab = a.broadcast_to(&out_shape);
    let bb = b.broadcast_to(&out_shape);
    let av = ab.buffer().f32();
    let mut b_offsets = Vec::with_capacity(n);
    bb.for_each_offset(|off| b_offsets.push(off));
    let bv = bb.buffer().f32();
    let mut i = 0usize;
    ab.for_each_offset(|off| {
        out[i] = op.apply(av[off], bv[b_offsets[i]]);
        i += 1;
    });
    out_shape
}

/// `out = op(a, b)` with broadcasting; result allocated on `tracker`.
pub fn binary(op: BinaryOp, a: &Tensor, b: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    let n = super::numel(&broadcast_shapes(a.shape(), b.shape()));
    let mut out = vec![0.0f32; n];
    let out_shape = binary_into(op, a, b, &mut out);
    Tensor::from_f32(out, &out_shape, tracker)
}

/// In-place elementwise binary: the output overwrites `target`, the dying
/// operand's contiguous storage (shape == output shape; arena in-place
/// aliasing). `target_is_lhs` records which side the target was; `other`
/// is the surviving operand (may broadcast), or `None` when both operands
/// were the same value (`op(x, x)`). Per-element arithmetic is identical
/// to [`binary_into`], so results are bitwise equal.
pub fn binary_inplace(
    op: BinaryOp,
    target: &mut [f32],
    target_shape: &[usize],
    target_is_lhs: bool,
    other: Option<&Tensor>,
) {
    let n = target.len();
    debug_assert_eq!(n, super::numel(target_shape), "binary_inplace shape");
    match other {
        None => {
            pool::par_rows(target, n, 1, n, |_r0, _r1, slab| {
                for o in slab.iter_mut() {
                    *o = op.apply(*o, *o);
                }
            });
        }
        Some(b) if b.shape() == target_shape && b.is_contiguous() => {
            let bv = b.f32_contiguous();
            pool::par_rows(target, n, 1, n, |r0, _r1, slab| {
                for (j, o) in slab.iter_mut().enumerate() {
                    let y = bv[r0 + j];
                    *o = if target_is_lhs {
                        op.apply(*o, y)
                    } else {
                        op.apply(y, *o)
                    };
                }
            });
        }
        Some(b) => {
            let bb = b.broadcast_to(target_shape);
            let src = bb.buffer().f32();
            let mut i = 0usize;
            bb.for_each_offset(|off| {
                let y = src[off];
                target[i] = if target_is_lhs {
                    op.apply(target[i], y)
                } else {
                    op.apply(y, target[i])
                };
                i += 1;
            });
        }
    }
}

/// Core of [`unary`]: computes `op(a)` into `out` (row-major).
pub fn unary_into(op: UnaryOp, a: &Tensor, out: &mut [f32]) {
    let n = a.numel();
    assert_eq!(out.len(), n, "unary_into length mismatch");
    if a.is_contiguous() {
        let av = a.f32_contiguous();
        // Transcendental ops are worth parallelizing at smaller sizes than
        // a plain copy-and-add — weight the work estimate accordingly.
        let weight: usize = match op {
            UnaryOp::Neg | UnaryOp::Abs | UnaryOp::Relu => 1,
            _ => 8,
        };
        pool::par_rows(out, n, 1, n.saturating_mul(weight), |r0, _r1, slab| {
            for (j, o) in slab.iter_mut().enumerate() {
                *o = op.apply(av[r0 + j]);
            }
        });
        return;
    }
    let src = a.buffer().f32();
    let mut i = 0usize;
    a.for_each_offset(|off| {
        out[i] = op.apply(src[off]);
        i += 1;
    });
}

/// `out = op(a)`; result allocated on `tracker`.
pub fn unary(op: UnaryOp, a: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    let mut out = vec![0.0f32; a.numel()];
    unary_into(op, a, &mut out);
    Tensor::from_f32(out, a.shape(), tracker)
}

/// In-place elementwise unary over a contiguous buffer (arena in-place
/// aliasing). Bitwise identical to [`unary_into`] on the same values.
pub fn unary_inplace(op: UnaryOp, v: &mut [f32]) {
    let n = v.len();
    let weight: usize = match op {
        UnaryOp::Neg | UnaryOp::Abs | UnaryOp::Relu => 1,
        _ => 8,
    };
    pool::par_rows(v, n, 1, n.saturating_mul(weight), |_r0, _r1, slab| {
        for o in slab.iter_mut() {
            *o = op.apply(*o);
        }
    });
}

/// Scalar right-operand convenience: `op(a, scalar)`.
pub fn binary_scalar(
    op: BinaryOp,
    a: &Tensor,
    scalar: f32,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let b = Tensor::from_f32(vec![scalar], &[1], None);
    binary(op, a, &b.broadcast_to(a.shape()), tracker)
}

/// Convert i32 tensor to f32 (or pass f32 through).
pub fn to_f32(a: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    match a.dtype() {
        super::DType::F32 => a.to_contiguous(tracker),
        super::DType::I32 => {
            let v = a.to_vec_i32().into_iter().map(|x| x as f32).collect();
            Tensor::from_f32(v, a.shape(), tracker)
        }
    }
}

/// Core of [`to_f32`] for planned-slot output: converts (i32) or copies
/// (f32) `a` into `out` in row-major order.
pub fn to_f32_into(a: &Tensor, out: &mut [f32]) {
    match a.dtype() {
        super::DType::F32 => a.copy_into_f32(out),
        super::DType::I32 => {
            assert_eq!(out.len(), a.numel(), "to_f32_into length mismatch");
            let src = a.buffer().i32();
            let mut i = 0usize;
            a.for_each_offset(|off| {
                out[i] = src[off] as f32;
                i += 1;
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_f32(data.to_vec(), shape, None)
    }

    #[test]
    fn add_same_shape() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]);
        let b = t(&[10., 20., 30., 40.], &[2, 2]);
        assert_eq!(
            binary(BinaryOp::Add, &a, &b, None).to_vec_f32(),
            vec![11., 22., 33., 44.]
        );
    }

    #[test]
    fn broadcast_row_and_col() {
        let a = t(&[1., 2., 3., 4., 5., 6.], &[2, 3]);
        let row = t(&[10., 20., 30.], &[3]);
        let r = binary(BinaryOp::Add, &a, &row, None);
        assert_eq!(r.to_vec_f32(), vec![11., 22., 33., 14., 25., 36.]);
        let col = t(&[100., 200.], &[2]).reshape(&[2, 1], None);
        let c = binary(BinaryOp::Add, &a, &col, None);
        assert_eq!(c.to_vec_f32(), vec![101., 102., 103., 204., 205., 206.]);
    }

    #[test]
    fn binary_on_strided_views() {
        // permuted lhs exercises the slow path
        let a = t(&[1., 2., 3., 4., 5., 6.], &[2, 3]).permute(&[1, 0]); // 3x2
        let b = t(&[1., 1., 1., 1., 1., 1.], &[3, 2]);
        let r = binary(BinaryOp::Add, &a, &b, None);
        assert_eq!(r.to_vec_f32(), vec![2., 5., 3., 6., 4., 7.]);
    }

    #[test]
    fn div_and_sub() {
        let a = t(&[8., 6.], &[2]);
        let b = t(&[2., 3.], &[2]);
        assert_eq!(binary(BinaryOp::Div, &a, &b, None).to_vec_f32(), vec![4., 2.]);
        assert_eq!(binary(BinaryOp::Sub, &a, &b, None).to_vec_f32(), vec![6., 3.]);
    }

    #[test]
    fn unary_math() {
        let a = t(&[-1., 0., 1., 4.], &[4]);
        assert_eq!(
            unary(UnaryOp::Relu, &a, None).to_vec_f32(),
            vec![0., 0., 1., 4.]
        );
        let s = unary(UnaryOp::Sqrt, &t(&[4., 9.], &[2]), None);
        assert_eq!(s.to_vec_f32(), vec![2., 3.]);
        let e = unary(UnaryOp::Exp, &t(&[0.], &[1]), None);
        assert!((e.to_vec_f32()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gelu_matches_reference_points() {
        // Reference values from jax.nn.gelu (tanh approximation).
        let x = t(&[-2.0, -1.0, 0.0, 1.0, 2.0], &[5]);
        let g = unary(UnaryOp::Gelu, &x, None).to_vec_f32();
        let expect = [-0.0454, -0.1588, 0.0, 0.8412, 1.9546];
        for (a, b) in g.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn unary_on_strided_view() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]).permute(&[1, 0]);
        let r = unary(UnaryOp::Neg, &a, None);
        assert_eq!(r.to_vec_f32(), vec![-1., -3., -2., -4.]);
    }

    #[test]
    fn binary_scalar_broadcast() {
        let a = t(&[1., 2.], &[2]);
        assert_eq!(
            binary_scalar(BinaryOp::Mul, &a, 3.0, None).to_vec_f32(),
            vec![3., 6.]
        );
    }

    #[test]
    fn to_f32_converts() {
        let a = Tensor::from_i32(vec![1, 2, 3], &[3], None);
        assert_eq!(to_f32(&a, None).to_vec_f32(), vec![1., 2., 3.]);
    }

    #[test]
    fn into_variants_match_allocating_kernels_bitwise() {
        let a = Tensor::rand(&[5, 7], 2.0, 21, None);
        let b = Tensor::rand(&[7], 2.0, 22, None); // broadcast rhs
        for op in [BinaryOp::Add, BinaryOp::Mul, BinaryOp::Div] {
            let want = binary(op, &a, &b, None).to_vec_f32();
            let mut out = vec![0.0f32; 35];
            binary_into(op, &a, &b, &mut out);
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                out.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
        let want = unary(UnaryOp::Gelu, &a, None).to_vec_f32();
        let mut out = vec![0.0f32; 35];
        unary_into(UnaryOp::Gelu, &a, &mut out);
        assert_eq!(want, out);
    }

    #[test]
    fn inplace_variants_match_allocating_kernels_bitwise() {
        let a = Tensor::rand(&[6, 4], 2.0, 31, None);
        let b = Tensor::rand(&[4], 2.0, 32, None);
        // unary in place
        let want = unary(UnaryOp::Tanh, &a, None).to_vec_f32();
        let mut v = a.to_vec_f32();
        unary_inplace(UnaryOp::Tanh, &mut v);
        assert_eq!(want, v);
        // binary into dead lhs (broadcast rhs)
        let want = binary(BinaryOp::Sub, &a, &b, None).to_vec_f32();
        let mut v = a.to_vec_f32();
        binary_inplace(BinaryOp::Sub, &mut v, a.shape(), true, Some(&b));
        assert_eq!(want, v);
        // binary into dead rhs (same shape)
        let c = Tensor::rand(&[6, 4], 2.0, 33, None);
        let want = binary(BinaryOp::Div, &c, &a, None).to_vec_f32();
        let mut v = a.to_vec_f32();
        binary_inplace(BinaryOp::Div, &mut v, a.shape(), false, Some(&c));
        assert_eq!(want, v);
        // op(x, x)
        let want = binary(BinaryOp::Mul, &a, &a, None).to_vec_f32();
        let mut v = a.to_vec_f32();
        binary_inplace(BinaryOp::Mul, &mut v, a.shape(), true, None);
        assert_eq!(want, v);
    }

    #[test]
    fn to_f32_into_matches() {
        let a = Tensor::from_i32(vec![3, -1, 7], &[3], None);
        let mut out = vec![0.0f32; 3];
        to_f32_into(&a, &mut out);
        assert_eq!(out, vec![3., -1., 7.]);
        let f = Tensor::rand(&[2, 3], 1.0, 4, None).permute(&[1, 0]);
        let mut out = vec![0.0f32; 6];
        to_f32_into(&f, &mut out);
        assert_eq!(out, f.to_vec_f32());
    }

    #[test]
    fn tracked_allocation_lands_on_tracker() {
        let tr = MemoryTracker::new();
        let a = t(&[1., 2.], &[2]);
        let b = t(&[3., 4.], &[2]);
        let _r = binary(BinaryOp::Add, &a, &b, Some(tr.clone()));
        assert_eq!(tr.current(), 8);
    }
}
