//! Blocked batched matmul.
//!
//! `matmul(a, b)`: `a: [..batch, M, K]`, `b: [..batch, K, N]` with numpy
//! batch broadcasting. The kernel is cache-blocked (MC×NC×KC tiles with a
//! transposed-B inner micro-kernel); efficiency degrades when M or N drop
//! below the tile size, which is exactly the *computation density* effect
//! the paper's micro cost term (Eq. 9) models: chunking a matmul into thin
//! slabs reduces achieved FLOP/s. We keep that behaviour honest rather than
//! special-casing small shapes.
//!
//! Large problems are data-parallel over batch × M-row blocks: every
//! worker owns a disjoint slab of C rows and runs the same blocked kernel
//! over them, so the per-element accumulation order — and therefore the
//! f32 result — is bitwise identical at any `AUTOCHUNK_THREADS` width.

use super::{broadcast_shapes, MemoryTracker, Tensor};
use crate::util::pool;

/// Cache-block sizes (f32 elements). MC*KC and KC*NC tiles fit in L2.
const MC: usize = 64;
const NC: usize = 64;
const KC: usize = 256;

/// Core of [`matmul`]: computes into `out` (zeroed, length batch·M·N) and
/// returns the output shape. Operand broadcast/contiguity materialization
/// is transient kernel workspace and still lands on `tracker`; only the
/// output allocation moved out, which is what lets the arena executor
/// write matmuls straight into planned slots.
pub fn matmul_into(
    a: &Tensor,
    b: &Tensor,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert!(a.rank() >= 2 && b.rank() >= 2, "matmul needs rank >= 2");
    let (m, k) = (a.shape()[a.rank() - 2], a.shape()[a.rank() - 1]);
    let (k2, n) = (b.shape()[b.rank() - 2], b.shape()[b.rank() - 1]);
    assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", a.shape(), b.shape());

    let batch_shape = broadcast_shapes(
        &a.shape()[..a.rank() - 2],
        &b.shape()[..b.rank() - 2],
    );
    let batch: usize = batch_shape.iter().product::<usize>().max(1);
    assert_eq!(out.len(), batch * m * n, "matmul_into length mismatch");

    // Broadcast operands to the full batch and materialize contiguously —
    // the strided-copy cost here is real and intentional.
    let mut a_full_shape = batch_shape.clone();
    a_full_shape.extend_from_slice(&[m, k]);
    let mut b_full_shape = batch_shape.clone();
    b_full_shape.extend_from_slice(&[k, n]);
    let ac = a.broadcast_to(&a_full_shape).to_contiguous(tracker.clone());
    let bc = b.broadcast_to(&b_full_shape).to_contiguous(tracker);
    let av = ac.f32_contiguous();
    let bv = bc.f32_contiguous();

    // Task grid: (batch element, MC-row block). Slabs tile `out` exactly
    // in task order, so the pool can hand each worker its own C rows.
    let row_blocks = m.div_ceil(MC).max(1);
    let mut lens = Vec::with_capacity(batch * row_blocks);
    for _ in 0..batch {
        for blk in 0..row_blocks {
            let mm = blk * MC;
            lens.push(MC.min(m.saturating_sub(mm)) * n);
        }
    }
    let work = 2usize.saturating_mul(batch * m * n).saturating_mul(k);
    pool::par_slabs(out, &lens, work, |t, c_slab| {
        let bi = t / row_blocks;
        let mm = (t % row_blocks) * MC;
        let mb = MC.min(m.saturating_sub(mm));
        let a_rows = &av[bi * m * k + mm * k..bi * m * k + (mm + mb) * k];
        let b_mat = &bv[bi * k * n..(bi + 1) * k * n];
        gemm_blocked(a_rows, b_mat, c_slab, mb, k, n);
    });

    let mut out_shape = batch_shape;
    out_shape.extend_from_slice(&[m, n]);
    out_shape
}

/// Batched matmul with broadcasting over leading dims.
pub fn matmul(a: &Tensor, b: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    assert!(a.rank() >= 2 && b.rank() >= 2, "matmul needs rank >= 2");
    let m = a.shape()[a.rank() - 2];
    let n = b.shape()[b.rank() - 1];
    let batch: usize = broadcast_shapes(&a.shape()[..a.rank() - 2], &b.shape()[..b.rank() - 2])
        .iter()
        .product::<usize>()
        .max(1);
    let mut out = vec![0.0f32; batch * m * n];
    let out_shape = matmul_into(a, b, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Row-major `C[m,n] += A[m,k] * B[k,n]`, cache-blocked.
fn gemm_blocked(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    // Pack B panels transposed so the micro-kernel reads both operands
    // sequentially.
    let mut b_pack = vec![0.0f32; KC * NC];
    for kk in (0..k).step_by(KC) {
        let kb = KC.min(k - kk);
        for nn in (0..n).step_by(NC) {
            let nb = NC.min(n - nn);
            // pack B[kk..kk+kb, nn..nn+nb] into column-major-ish panel
            for j in 0..nb {
                for p in 0..kb {
                    b_pack[j * kb + p] = b[(kk + p) * n + nn + j];
                }
            }
            for mm in (0..m).step_by(MC) {
                let mb = MC.min(m - mm);
                for i in 0..mb {
                    let a_row = &a[(mm + i) * k + kk..(mm + i) * k + kk + kb];
                    let c_row = &mut c[(mm + i) * n + nn..(mm + i) * n + nn + nb];
                    for j in 0..nb {
                        let b_col = &b_pack[j * kb..j * kb + kb];
                        // dot product, 4-way unrolled
                        let mut acc0 = 0.0f32;
                        let mut acc1 = 0.0f32;
                        let mut acc2 = 0.0f32;
                        let mut acc3 = 0.0f32;
                        let chunks = kb / 4;
                        for q in 0..chunks {
                            let base = q * 4;
                            acc0 += a_row[base] * b_col[base];
                            acc1 += a_row[base + 1] * b_col[base + 1];
                            acc2 += a_row[base + 2] * b_col[base + 2];
                            acc3 += a_row[base + 3] * b_col[base + 3];
                        }
                        let mut acc = acc0 + acc1 + acc2 + acc3;
                        for q in chunks * 4..kb {
                            acc += a_row[q] * b_col[q];
                        }
                        c_row[j] += acc;
                    }
                }
            }
        }
    }
}

/// FLOPs of a matmul between these shapes (2*M*N*K per batch element).
pub fn matmul_flops(a_shape: &[usize], b_shape: &[usize]) -> u64 {
    let m = a_shape[a_shape.len() - 2] as u64;
    let k = a_shape[a_shape.len() - 1] as u64;
    let n = b_shape[b_shape.len() - 1] as u64;
    let batch: u64 = broadcast_shapes(
        &a_shape[..a_shape.len() - 2],
        &b_shape[..b_shape.len() - 2],
    )
    .iter()
    .map(|&x| x as u64)
    .product::<u64>()
    .max(1);
    2 * batch * m * n * k
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_f32(data.to_vec(), shape, None)
    }

    /// Naive reference matmul for testing the blocked kernel.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn small_matmul() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]);
        let b = t(&[1., 0., 0., 1.], &[2, 2]);
        assert_eq!(matmul(&a, &b, None).to_vec_f32(), vec![1., 2., 3., 4.]);
    }

    #[test]
    fn rectangular_matches_naive() {
        for &(m, k, n) in &[(3, 5, 7), (65, 17, 130), (128, 300, 64), (1, 256, 1)] {
            let a = Tensor::rand(&[m, k], 1.0, 1, None);
            let b = Tensor::rand(&[k, n], 1.0, 2, None);
            let got = matmul(&a, &b, None).to_vec_f32();
            let want = naive(&a.to_vec_f32(), &b.to_vec_f32(), m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "({m},{k},{n}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn batched_matmul() {
        let a = Tensor::rand(&[2, 3, 4], 1.0, 3, None);
        let b = Tensor::rand(&[2, 4, 5], 1.0, 4, None);
        let c = matmul(&a, &b, None);
        assert_eq!(c.shape(), &[2, 3, 5]);
        // check batch 1 against naive
        let a1 = a.slice_axis(0, 1, 1).reshape(&[3, 4], None);
        let b1 = b.slice_axis(0, 1, 1).reshape(&[4, 5], None);
        let want = naive(&a1.to_vec_f32(), &b1.to_vec_f32(), 3, 4, 5);
        let got = c.slice_axis(0, 1, 1).to_vec_f32();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn batch_broadcasting() {
        // [2,3,4] x [4,5] -> [2,3,5]
        let a = Tensor::rand(&[2, 3, 4], 1.0, 5, None);
        let b = Tensor::rand(&[4, 5], 1.0, 6, None);
        let c = matmul(&a, &b, None);
        assert_eq!(c.shape(), &[2, 3, 5]);
        let a0 = a.slice_axis(0, 0, 1).reshape(&[3, 4], None);
        let want = naive(&a0.to_vec_f32(), &b.to_vec_f32(), 3, 4, 5);
        let got = c.slice_axis(0, 0, 1).to_vec_f32();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn transposed_view_operand() {
        let a = Tensor::rand(&[4, 3], 1.0, 7, None).permute(&[1, 0]); // [3,4] strided
        let b = Tensor::rand(&[4, 2], 1.0, 8, None);
        let c = matmul(&a, &b, None);
        assert_eq!(c.shape(), &[3, 2]);
        let want = naive(&a.to_vec_f32(), &b.to_vec_f32(), 3, 4, 2);
        for (g, w) in c.to_vec_f32().iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn flops_accounting() {
        assert_eq!(matmul_flops(&[2, 3], &[3, 4]), 2 * 2 * 3 * 4);
        assert_eq!(matmul_flops(&[8, 2, 3], &[8, 3, 4]), 8 * 2 * 2 * 3 * 4);
        assert_eq!(matmul_flops(&[8, 2, 3], &[3, 4]), 8 * 2 * 2 * 3 * 4);
    }
}
