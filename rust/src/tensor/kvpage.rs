//! Paged KV-cache block pool (DESIGN.md §14).
//!
//! Where [`super::kvcache::KvCache`] pins one capacity-shaped buffer per
//! layer for a request's whole lifetime, a [`BlockPool`] carves cache
//! storage into fixed `block_tokens`-sized **blocks** — per layer, a pair
//! of `[heads, block_tokens, head_dim]` K/V tensors — handed out by a
//! free-list allocator and referenced by per-request [`BlockTable`]s.
//! Resident bytes are therefore proportional to *positions actually
//! cached* (rounded up to the block), not to bucket capacity: a request
//! that generates 8 tokens from an 8-token prompt holds one block, not a
//! 512-token cache.
//!
//! Blocks are **refcounted** so requests with identical prompt prefixes
//! can share prefix blocks (the sharing policy — keys, copy-on-write on
//! divergence — lives in `coordinator::cache_manager`; the pool only
//! provides the mechanism: `retain`/`release`/`copy_block` and the
//! exclusivity check in [`BlockPool::write_rows`]).
//!
//! Memory contract: a block's tensors are allocated on the pool's
//! [`MemoryTracker`] when the block is handed out and dropped when its
//! refcount returns to zero, so `resident_bytes()` — `blocks_in_use ·
//! block_bytes` — is exactly what the tracker sees. The free list
//! conserves identity: `blocks_in_use + free_blocks == pool_blocks` at
//! every step (`rust/tests/kvpage_fuzz.rs` fuzzes this invariant along
//! with refcount discipline and copy-on-write stability).

use super::{MemoryTracker, Tensor};

/// Index of a block slot within its [`BlockPool`].
pub type BlockId = usize;

#[derive(Debug, Default)]
struct Slot {
    /// Live references (block tables holding this block). 0 = free.
    refs: usize,
    /// Per-layer K tensors `[heads, block_tokens, head_dim]` (empty while
    /// the slot is free — freed blocks hold no storage).
    ks: Vec<Tensor>,
    /// Per-layer V tensors.
    vs: Vec<Tensor>,
}

/// Fixed-capacity pool of refcounted KV blocks with a free-list allocator.
#[derive(Debug)]
pub struct BlockPool {
    layers: usize,
    heads: usize,
    block_tokens: usize,
    head_dim: usize,
    tracker: Option<MemoryTracker>,
    slots: Vec<Slot>,
    /// Free slot ids; `alloc` pops the back (lowest id first from a fresh
    /// pool — deterministic at any pool width since callers allocate in
    /// post-wave serial order).
    free: Vec<BlockId>,
    in_use: usize,
    /// Lifetime counters (metrics / fuzz cross-checks).
    total_allocs: usize,
    total_frees: usize,
}

impl BlockPool {
    /// A pool of `pool_blocks` slots. Storage is lazy: an empty pool holds
    /// no tensors, and admission-control byte budgets see only blocks in
    /// use.
    pub fn new(
        layers: usize,
        heads: usize,
        block_tokens: usize,
        head_dim: usize,
        pool_blocks: usize,
        tracker: Option<MemoryTracker>,
    ) -> BlockPool {
        assert!(layers > 0 && heads > 0 && block_tokens > 0 && head_dim > 0);
        assert!(pool_blocks > 0, "pool needs at least one block");
        let slots = (0..pool_blocks).map(|_| Slot::default()).collect();
        let free: Vec<BlockId> = (0..pool_blocks).rev().collect();
        BlockPool {
            layers,
            heads,
            block_tokens,
            head_dim,
            tracker,
            slots,
            free,
            in_use: 0,
            total_allocs: 0,
            total_frees: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Bytes one block pins while allocated (K and V, all layers).
    pub fn block_bytes(&self) -> usize {
        2 * self.layers * self.heads * self.block_tokens * self.head_dim * 4
    }

    /// Total slots (the conservation denominator).
    pub fn pool_blocks(&self) -> usize {
        self.slots.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Bytes currently resident: blocks in use × block bytes — by
    /// construction exactly the tracker bytes this pool holds.
    pub fn resident_bytes(&self) -> usize {
        self.in_use * self.block_bytes()
    }

    /// (lifetime allocs, lifetime frees) — fuzz/metrics counters.
    pub fn alloc_stats(&self) -> (usize, usize) {
        (self.total_allocs, self.total_frees)
    }

    /// Live references to `id` (0 = free slot).
    pub fn ref_count(&self, id: BlockId) -> usize {
        self.slots[id].refs
    }

    /// Hand out a block (refcount 1), allocating its tensors on the
    /// tracker. `None` when the pool is exhausted — the serving tier's
    /// admission control reserves blocks up front precisely so its own
    /// allocations never see this.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        let slot = &mut self.slots[id];
        debug_assert_eq!(slot.refs, 0, "free-listed block has references");
        debug_assert!(slot.ks.is_empty(), "free-listed block holds storage");
        let shape = [self.heads, self.block_tokens, self.head_dim];
        slot.refs = 1;
        slot.ks = (0..self.layers).map(|_| Tensor::zeros(&shape, self.tracker.clone())).collect();
        slot.vs = (0..self.layers).map(|_| Tensor::zeros(&shape, self.tracker.clone())).collect();
        self.in_use += 1;
        self.total_allocs += 1;
        Some(id)
    }

    /// Add a reference (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.slots[id].refs > 0, "retain on free block {id}");
        self.slots[id].refs += 1;
    }

    /// Drop a reference; returns `true` when the block was freed (storage
    /// dropped, slot returned to the free list). Releasing a free block
    /// is a double free and panics.
    pub fn release(&mut self, id: BlockId) -> bool {
        let slot = &mut self.slots[id];
        assert!(slot.refs > 0, "double free of block {id}");
        slot.refs -= 1;
        if slot.refs > 0 {
            return false;
        }
        slot.ks.clear();
        slot.vs.clear();
        self.free.push(id);
        self.in_use -= 1;
        self.total_frees += 1;
        true
    }

    /// The block's K tensor for `layer` (cheap clone of the shared
    /// buffer; drop it before the next write to the block).
    pub fn k(&self, id: BlockId, layer: usize) -> Tensor {
        assert!(self.slots[id].refs > 0, "read of free block {id}");
        self.slots[id].ks[layer].clone()
    }

    /// The block's V tensor for `layer`.
    pub fn v(&self, id: BlockId, layer: usize) -> Tensor {
        assert!(self.slots[id].refs > 0, "read of free block {id}");
        self.slots[id].vs[layer].clone()
    }

    /// Write `k_src`/`v_src` — `[heads, n, head_dim]` views — into rows
    /// `at..at+n` of the block for `layer`. Requires exclusive ownership
    /// (refcount 1): writing a shared block means a missed copy-on-write,
    /// which this assert turns into a loud failure instead of corrupted
    /// sibling reads.
    pub fn write_rows(&mut self, id: BlockId, layer: usize, at: usize, k_src: &Tensor, v_src: &Tensor) {
        assert_eq!(self.slots[id].refs, 1, "write to shared block {id} (copy-on-write missed)");
        let (h, bt, dh) = (self.heads, self.block_tokens, self.head_dim);
        let n = k_src.shape()[1];
        assert!(at + n <= bt, "rows {at}+{n} over block size {bt}");
        assert_eq!(k_src.shape(), &[h, n, dh][..], "write k shape");
        assert_eq!(v_src.shape(), &[h, n, dh][..], "write v shape");
        let ksrc = k_src.to_vec_f32();
        let kd = self.slots[id].ks[layer].f32_mut().expect("block k aliased during write");
        for hi in 0..h {
            for r in 0..n {
                kd[hi * bt * dh + (at + r) * dh..hi * bt * dh + (at + r + 1) * dh]
                    .copy_from_slice(&ksrc[(hi * n + r) * dh..(hi * n + r + 1) * dh]);
            }
        }
        let vsrc = v_src.to_vec_f32();
        let vd = self.slots[id].vs[layer].f32_mut().expect("block v aliased during write");
        for hi in 0..h {
            for r in 0..n {
                vd[hi * bt * dh + (at + r) * dh..hi * bt * dh + (at + r + 1) * dh]
                    .copy_from_slice(&vsrc[(hi * n + r) * dh..(hi * n + r + 1) * dh]);
            }
        }
    }

    /// Copy-on-write helper: copy every layer's K/V bytes from `src`
    /// (shared) into `dst` (freshly allocated, exclusive).
    pub fn copy_block(&mut self, dst: BlockId, src: BlockId) {
        assert_ne!(dst, src, "copy onto itself");
        assert!(self.slots[src].refs > 0, "copy from free block {src}");
        assert_eq!(self.slots[dst].refs, 1, "copy into shared block {dst}");
        for l in 0..self.layers {
            let kdata = self.slots[src].ks[l].to_vec_f32();
            let vdata = self.slots[src].vs[l].to_vec_f32();
            self.slots[dst].ks[l]
                .f32_mut()
                .expect("dst k aliased during copy")
                .copy_from_slice(&kdata);
            self.slots[dst].vs[l]
                .f32_mut()
                .expect("dst v aliased during copy")
                .copy_from_slice(&vdata);
        }
    }
}

/// A request's view into the pool: ordered block ids covering its cached
/// positions plus the logical length. Position `p` lives in
/// `blocks[p / block_tokens]` at row `p % block_tokens`.
#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
    len: usize,
}

impl BlockTable {
    pub fn new() -> BlockTable {
        BlockTable::default()
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Logical length: number of valid (attended) cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn last_block(&self) -> Option<BlockId> {
        self.blocks.last().copied()
    }

    /// Append a block to the tail (the caller owns refcounting).
    pub fn push_block(&mut self, id: BlockId) {
        self.blocks.push(id);
    }

    /// Remove and return the tail block — the rollback of a failed
    /// multi-block grow (the caller owns refcounting, as with
    /// [`BlockTable::push_block`]).
    pub fn pop_block(&mut self) -> Option<BlockId> {
        self.blocks.pop()
    }

    /// Replace the block at `index` (copy-on-write swap); returns the
    /// previous id so the caller can release its reference.
    pub fn swap_block(&mut self, index: usize, id: BlockId) -> BlockId {
        std::mem::replace(&mut self.blocks[index], id)
    }

    /// Set the logical length (after seeding). Coverage — `len` positions
    /// fitting the held blocks — is the pool owner's invariant; the table
    /// itself does not know `block_tokens`.
    pub fn set_len(&mut self, len: usize) {
        self.len = len;
    }

    /// Advance the logical length after appending one position.
    pub fn advance(&mut self) {
        self.len += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pool(pool_blocks: usize, tracker: Option<MemoryTracker>) -> BlockPool {
        BlockPool::new(2, 2, 4, 3, pool_blocks, tracker)
    }

    #[test]
    fn alloc_free_conservation_and_tracker() {
        let tr = MemoryTracker::new();
        let mut p = tiny_pool(3, Some(tr.clone()));
        assert_eq!(p.block_bytes(), 2 * 2 * 2 * 4 * 3 * 4);
        assert_eq!(p.pool_blocks(), 3);
        assert_eq!(tr.current(), 0);

        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.blocks_in_use() + p.free_blocks(), p.pool_blocks());
        assert_eq!(p.blocks_in_use(), 2);
        assert_eq!(tr.current(), p.resident_bytes());
        assert_eq!(p.resident_bytes(), 2 * p.block_bytes());

        assert!(p.release(a));
        assert_eq!(tr.current(), p.block_bytes());
        let c = p.alloc().unwrap();
        let d = p.alloc().unwrap();
        assert!(p.alloc().is_none(), "pool must be exhausted");
        assert_eq!(p.blocks_in_use(), 3);
        for id in [b, c, d] {
            assert!(p.release(id));
        }
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.free_blocks(), p.pool_blocks());
        assert_eq!(tr.current(), 0);
    }

    #[test]
    fn refcounts_free_exactly_once() {
        let mut p = tiny_pool(2, None);
        let a = p.alloc().unwrap();
        p.retain(a);
        p.retain(a);
        assert_eq!(p.ref_count(a), 3);
        assert!(!p.release(a));
        assert!(!p.release(a));
        assert!(p.release(a), "last release frees");
        assert_eq!(p.ref_count(a), 0);
        assert_eq!(p.free_blocks(), 2);
        assert_eq!(p.alloc_stats(), (1, 1));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = tiny_pool(2, None);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    #[should_panic(expected = "copy-on-write missed")]
    fn write_to_shared_block_panics() {
        let mut p = tiny_pool(2, None);
        let a = p.alloc().unwrap();
        p.retain(a);
        let k = Tensor::zeros(&[2, 1, 3], None);
        let v = Tensor::zeros(&[2, 1, 3], None);
        p.write_rows(a, 0, 0, &k, &v);
    }

    #[test]
    fn write_read_roundtrip_with_strided_source() {
        let mut p = tiny_pool(1, None);
        let a = p.alloc().unwrap();
        // rows come from a strided slice of a bigger [h, s, dh] tensor,
        // exactly how prefill outputs are carved into blocks
        let big = Tensor::rand(&[2, 10, 3], 1.0, 7, None);
        let ks = big.slice_axis(1, 4, 2); // [2, 2, 3], non-contiguous
        assert!(!ks.is_contiguous());
        p.write_rows(a, 1, 1, &ks, &ks);
        let got = p.k(a, 1);
        for hi in 0..2 {
            for r in 0..2 {
                for d in 0..3 {
                    let want = big.at(&[hi, 4 + r, d]);
                    assert_eq!(got.at(&[hi, 1 + r, d]).to_bits(), want.to_bits());
                }
            }
        }
        // untouched rows stay zero
        assert_eq!(got.at(&[0, 0, 0]), 0.0);
        assert_eq!(got.at(&[1, 3, 2]), 0.0);
    }

    #[test]
    fn copy_block_is_bitwise() {
        let mut p = tiny_pool(2, None);
        let a = p.alloc().unwrap();
        let rows = Tensor::rand(&[2, 4, 3], 1.0, 9, None);
        let vrows = Tensor::rand(&[2, 4, 3], 1.0, 10, None);
        for l in 0..2 {
            p.write_rows(a, l, 0, &rows, &vrows);
        }
        let b = p.alloc().unwrap();
        p.copy_block(b, a);
        for l in 0..2 {
            let ka: Vec<u32> = p.k(a, l).to_vec_f32().iter().map(|x| x.to_bits()).collect();
            let kb: Vec<u32> = p.k(b, l).to_vec_f32().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ka, kb, "layer {l} K");
            let va: Vec<u32> = p.v(a, l).to_vec_f32().iter().map(|x| x.to_bits()).collect();
            let vb: Vec<u32> = p.v(b, l).to_vec_f32().iter().map(|x| x.to_bits()).collect();
            assert_eq!(va, vb, "layer {l} V");
        }
    }

    #[test]
    fn block_table_position_mapping() {
        let mut t = BlockTable::new();
        assert!(t.is_empty());
        t.push_block(5);
        t.push_block(2);
        t.set_len(6);
        assert_eq!(t.len(), 6);
        assert_eq!(t.blocks(), &[5, 2]);
        assert_eq!(t.last_block(), Some(2));
        t.advance();
        assert_eq!(t.len(), 7);
        let old = t.swap_block(1, 9);
        assert_eq!(old, 2);
        assert_eq!(t.blocks(), &[5, 9]);
    }
}
