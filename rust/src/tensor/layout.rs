//! Layout ops: concat, pad, gather, split helpers.
//!
//! `concat` is the runtime cost center of chunked execution (each chunk's
//! output is copied into the joined result). Its cost depends on the chunk
//! axis: concatenating along an outer axis is a few large memcpys, along an
//! inner axis many small ones — the stride term of Eq. 9 in the flesh.

use super::{contiguous_strides, DType, MemoryTracker, Tensor};

/// Output shape of concatenating `parts` along `axis` (validates ranks
/// and non-axis extents).
pub fn concat_shape(parts: &[Tensor], axis: usize) -> Vec<usize> {
    assert!(!parts.is_empty(), "concat of nothing");
    let rank = parts[0].rank();
    assert!(axis < rank);
    let mut out_shape = parts[0].shape().to_vec();
    let mut total = 0usize;
    for p in parts {
        assert_eq!(p.rank(), rank, "concat rank mismatch");
        for d in 0..rank {
            if d != axis {
                assert_eq!(p.shape()[d], out_shape[d], "concat shape mismatch");
            }
        }
        total += p.shape()[axis];
    }
    out_shape[axis] = total;
    out_shape
}

/// Core of [`concat`]: joins `parts` along `axis` into `out` and returns
/// the output shape. Non-contiguous parts are materialized transiently on
/// `tracker` before their copy.
pub fn concat_into(
    parts: &[Tensor],
    axis: usize,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    let out_shape = concat_shape(parts, axis);
    let n = super::numel(&out_shape);
    assert_eq!(out.len(), n, "concat_into length mismatch");

    // Copy each part row-block by row-block. `outer` indexes everything
    // before `axis`; for each outer index, each part contributes a
    // contiguous run of part_axis_len * inner elements.
    let inner: usize = out_shape[axis + 1..].iter().product();
    let outer: usize = out_shape[..axis].iter().product();
    let out_slab = out_shape[axis] * inner;
    let mut axis_off = 0usize;
    for p in parts {
        let pc = p.to_contiguous(tracker.clone());
        let src = pc.f32_contiguous();
        let p_axis = p.shape()[axis];
        let run = p_axis * inner;
        for o in 0..outer.max(1) {
            let dst_base = o * out_slab + axis_off * inner;
            out[dst_base..dst_base + run].copy_from_slice(&src[o * run..(o + 1) * run]);
        }
        axis_off += p_axis;
    }
    out_shape
}

/// Concatenate tensors along `axis`. All shapes must match except `axis`.
pub fn concat(parts: &[Tensor], axis: usize, tracker: Option<MemoryTracker>) -> Tensor {
    let shape = concat_shape(parts, axis);
    let mut out = vec![0.0f32; super::numel(&shape)];
    let out_shape = concat_into(parts, axis, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Zero-pad `a` with `(lo, hi)` per dimension.
pub fn pad(a: &Tensor, padding: &[(usize, usize)], tracker: Option<MemoryTracker>) -> Tensor {
    assert_eq!(padding.len(), a.rank());
    let out_shape: Vec<usize> = a
        .shape()
        .iter()
        .zip(padding)
        .map(|(&d, &(lo, hi))| d + lo + hi)
        .collect();
    let out_strides = contiguous_strides(&out_shape);
    let mut out = vec![0.0f32; super::numel(&out_shape)];
    let ac = a.to_contiguous(tracker.clone());
    let src = ac.f32_contiguous();

    // Walk source indices; compute destination offset with the pad shift.
    let a_shape = a.shape().to_vec();
    let rank = a_shape.len();
    let mut idx = vec![0usize; rank];
    for &v in src {
        let mut off = 0isize;
        for i in 0..rank {
            off += (idx[i] + padding[i].0) as isize * out_strides[i];
        }
        out[off as usize] = v;
        for i in (0..rank).rev() {
            idx[i] += 1;
            if idx[i] < a_shape[i] {
                break;
            }
            idx[i] = 0;
        }
    }
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Core of [`gather_rows`]: looks rows up into `out`, returning the
/// output shape.
pub fn gather_rows_into(
    table: &Tensor,
    ids: &Tensor,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert_eq!(table.rank(), 2, "gather table must be [V, D]");
    assert_eq!(ids.dtype(), DType::I32, "gather ids must be i32");
    let v = table.shape()[0];
    let d = table.shape()[1];
    let tc = table.to_contiguous(tracker);
    let tv = tc.f32_contiguous();
    let flat_ids = ids.to_vec_i32();
    assert_eq!(out.len(), flat_ids.len() * d, "gather_into length mismatch");
    for (i, &id) in flat_ids.iter().enumerate() {
        let id = id as usize;
        assert!(id < v, "gather id {id} out of range {v}");
        out[i * d..(i + 1) * d].copy_from_slice(&tv[id * d..(id + 1) * d]);
    }
    let mut out_shape = ids.shape().to_vec();
    out_shape.push(d);
    out_shape
}

/// Embedding lookup: `table: [V, D]`, `ids: i32 [..]` → `[.., D]`.
pub fn gather_rows(table: &Tensor, ids: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    let mut out = vec![0.0f32; ids.numel() * table.shape()[1]];
    let out_shape = gather_rows_into(table, ids, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Split into `n` nearly-equal parts along `axis` (last part may be short).
/// Returns zero-copy views.
pub fn split(a: &Tensor, axis: usize, n: usize) -> Vec<Tensor> {
    assert!(n >= 1 && axis < a.rank());
    let len = a.shape()[axis];
    let step = len.div_ceil(n);
    let mut parts = Vec::new();
    let mut start = 0;
    while start < len {
        let take = step.min(len - start);
        parts.push(a.slice_axis(axis, start, take));
        start += take;
    }
    parts
}

/// Core of [`upsample2x_nchw`]: writes the upsample into `out`, returning
/// the output shape.
pub fn upsample2x_into(a: &Tensor, out: &mut [f32], tracker: Option<MemoryTracker>) -> Vec<usize> {
    assert_eq!(a.rank(), 4, "upsample expects NCHW");
    let (n, c, h, w) = (a.shape()[0], a.shape()[1], a.shape()[2], a.shape()[3]);
    assert_eq!(out.len(), n * c * 4 * h * w, "upsample_into length mismatch");
    let ac = a.to_contiguous(tracker);
    let src = ac.f32_contiguous();
    let (oh, ow) = (2 * h, 2 * w);
    for ni in 0..n {
        for ci in 0..c {
            let sbase = (ni * c + ci) * h * w;
            let dbase = (ni * c + ci) * oh * ow;
            for y in 0..oh {
                for x in 0..ow {
                    out[dbase + y * ow + x] = src[sbase + (y / 2) * w + (x / 2)];
                }
            }
        }
    }
    vec![n, c, oh, ow]
}

/// Nearest-neighbor 2× spatial upsample for NCHW tensors (UNet decoder).
pub fn upsample2x_nchw(a: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    let mut out = vec![0.0f32; a.numel() * 4];
    let out_shape = upsample2x_into(a, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::from_f32(data.to_vec(), shape, None)
    }

    #[test]
    fn concat_axis0() {
        let a = t(&[1., 2.], &[1, 2]);
        let b = t(&[3., 4., 5., 6.], &[2, 2]);
        let c = concat(&[a, b], 0, None);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.to_vec_f32(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn concat_axis1() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]);
        let b = t(&[9., 9.], &[2, 1]);
        let c = concat(&[a, b], 1, None);
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.to_vec_f32(), vec![1., 2., 9., 3., 4., 9.]);
    }

    #[test]
    fn concat_middle_axis_3d() {
        let a = Tensor::iota(&[2, 2, 2], 2, None);
        let b = Tensor::full(7.0, &[2, 1, 2], None);
        let c = concat(&[a.clone(), b], 1, None);
        assert_eq!(c.shape(), &[2, 3, 2]);
        // first batch: rows of a then row of 7s
        assert_eq!(
            c.slice_axis(0, 0, 1).to_vec_f32(),
            vec![0., 1., 0., 1., 7., 7.]
        );
    }

    #[test]
    fn split_then_concat_roundtrip() {
        let a = Tensor::rand(&[7, 4], 1.0, 13, None);
        for n in 1..=7 {
            let parts = split(&a, 0, n);
            let joined = concat(&parts, 0, None);
            assert_eq!(joined.to_vec_f32(), a.to_vec_f32(), "n={n}");
        }
        // inner axis: 4 elements into n=3 → ceil(4/3)=2-wide steps → 2 parts
        let parts = split(&a, 1, 3);
        assert_eq!(parts.len(), 2);
        let joined = concat(&parts, 1, None);
        assert_eq!(joined.to_vec_f32(), a.to_vec_f32());
    }

    #[test]
    fn split_uneven() {
        let a = Tensor::rand(&[10], 1.0, 17, None);
        let parts = split(&a, 0, 4);
        let lens: Vec<usize> = parts.iter().map(|p| p.shape()[0]).collect();
        assert_eq!(lens.iter().sum::<usize>(), 10);
        assert_eq!(lens, vec![3, 3, 3, 1]);
    }

    #[test]
    fn pad_2d() {
        let a = t(&[1., 2., 3., 4.], &[2, 2]);
        let p = pad(&a, &[(1, 0), (0, 1)], None);
        assert_eq!(p.shape(), &[3, 3]);
        assert_eq!(
            p.to_vec_f32(),
            vec![0., 0., 0., 1., 2., 0., 3., 4., 0.]
        );
    }

    #[test]
    fn gather_rows_lookup() {
        let table = t(&[0., 0., 1., 1., 2., 2.], &[3, 2]);
        let ids = Tensor::from_i32(vec![2, 0, 1, 1], &[2, 2], None);
        let g = gather_rows(&table, &ids, None);
        assert_eq!(g.shape(), &[2, 2, 2]);
        assert_eq!(g.to_vec_f32(), vec![2., 2., 0., 0., 1., 1., 1., 1.]);
    }

    #[test]
    fn upsample_doubles_spatial() {
        let a = t(&[1., 2., 3., 4.], &[1, 1, 2, 2]);
        let u = upsample2x_nchw(&a, None);
        assert_eq!(u.shape(), &[1, 1, 4, 4]);
        assert_eq!(
            u.to_vec_f32(),
            vec![1., 1., 2., 2., 1., 1., 2., 2., 3., 3., 4., 4., 3., 3., 4., 4.]
        );
    }

    #[test]
    fn concat_tracked_memory() {
        let tr = MemoryTracker::new();
        let a = Tensor::zeros(&[4, 4], None);
        let b = Tensor::zeros(&[4, 4], None);
        let c = concat(&[a, b], 0, Some(tr.clone()));
        assert_eq!(tr.current(), c.byte_size());
    }
}
