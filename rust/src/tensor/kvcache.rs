//! Persistent KV cache for autoregressive decode (DESIGN.md §13).
//!
//! One [`KvCache`] holds, per transformer layer, a pair of
//! capacity-shaped `[heads, capacity, head_dim]` tensors that live
//! *across* executions: the prefill seeds them, every decode step reads
//! them as persistent graph inputs and appends the new token's K/V rows.
//! Rows at index ≥ `len` are stale by contract — the decode graph's
//! position masking makes them exact no-ops, so they are never zeroed.
//! Stale rows are always *finite* (seeded or appended computed values):
//! the fused decode path never reads masked bytes at all, while the dense
//! path computes scores from them before the additive mask drives the
//! result below the exp-underflow threshold — which needs finiteness and
//! bounded magnitude, both guaranteed for computed K/V rows.
//!
//! Memory contract: the backing tensors are allocated on the serve run's
//! [`MemoryTracker`] at full capacity, so a cache's **resident bytes are
//! part of the measured peak** from creation to eviction — exactly what
//! the engine's admission control charges (`planned_peak +
//! resident_kv_bytes`). Appends mutate in place through
//! [`Tensor::f32_mut`]: they require that no execution still holds a view
//! of the cache (the engine appends strictly between steps) and move no
//! tracker counters — resident bytes are constant for the cache's
//! lifetime.

use super::{MemoryTracker, Tensor};

/// Per-request persistent KV state: `layers` pairs of
/// `[heads, capacity, head_dim]` tensors plus the logical length.
#[derive(Debug)]
pub struct KvCache {
    ks: Vec<Tensor>,
    vs: Vec<Tensor>,
    heads: usize,
    head_dim: usize,
    capacity: usize,
    len: usize,
}

impl KvCache {
    /// Allocate a cache at full capacity on `tracker` (resident bytes
    /// count toward the run's measured peak immediately — admission must
    /// have reserved them).
    pub fn new(
        layers: usize,
        heads: usize,
        capacity: usize,
        head_dim: usize,
        tracker: Option<MemoryTracker>,
    ) -> KvCache {
        assert!(layers > 0 && heads > 0 && capacity > 0 && head_dim > 0);
        let shape = [heads, capacity, head_dim];
        let ks = (0..layers).map(|_| Tensor::zeros(&shape, tracker.clone())).collect();
        let vs = (0..layers).map(|_| Tensor::zeros(&shape, tracker.clone())).collect();
        KvCache {
            ks,
            vs,
            heads,
            head_dim,
            capacity,
            len: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.ks.len()
    }

    /// Logical length: number of valid (attended) positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Bytes this cache reserves at full bucket capacity (K and V, all
    /// layers) — what admission must charge, since a contiguous cache
    /// allocates its whole capacity up front.
    pub fn capacity_bytes(&self) -> usize {
        2 * self.layers() * self.heads * self.capacity * self.head_dim * 4
    }

    /// Bytes this cache actually holds on the tracker right now. For the
    /// contiguous cache this *equals* [`KvCache::capacity_bytes`] — the
    /// full buffers are allocated at construction — which is exactly the
    /// inefficiency the paged pool ([`super::kvpage::BlockPool`], whose
    /// `resident_bytes` tracks blocks in use) exists to fix. Metrics
    /// report this value so `resident_kv_high_water_bytes` means "bytes
    /// held", not "bytes reserved", under either backend (DESIGN.md §14).
    pub fn resident_bytes(&self) -> usize {
        self.capacity_bytes()
    }

    /// Bulk-seed one layer from prefill outputs (full `[h, cap, dh]`
    /// tensors; rows ≥ the prompt length hold masked padding values).
    /// Call [`KvCache::set_len`] once every layer is seeded.
    pub fn seed(&mut self, layer: usize, k: &Tensor, v: &Tensor) {
        let want = [self.heads, self.capacity, self.head_dim];
        assert_eq!(k.shape(), &want[..], "seed k shape");
        assert_eq!(v.shape(), &want[..], "seed v shape");
        let kd = self.ks[layer].f32_mut().expect("cache k aliased during seed");
        k.copy_into_f32(kd);
        let vd = self.vs[layer].f32_mut().expect("cache v aliased during seed");
        v.copy_into_f32(vd);
    }

    /// Set the logical length (after seeding all layers).
    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.capacity, "len {len} over capacity {}", self.capacity);
        self.len = len;
    }

    /// Write one new token's `[h, 1, dh]` K/V rows at position `len` for
    /// `layer`. Call [`KvCache::advance`] once every layer is appended.
    pub fn append(&mut self, layer: usize, k_row: &Tensor, v_row: &Tensor) {
        assert!(self.len < self.capacity, "cache full at {}", self.len);
        let want = [self.heads, 1, self.head_dim];
        assert_eq!(k_row.shape(), &want[..], "append k shape");
        assert_eq!(v_row.shape(), &want[..], "append v shape");
        let (cap, dh, at) = (self.capacity, self.head_dim, self.len);
        let ksrc = k_row.to_vec_f32();
        let kd = self.ks[layer].f32_mut().expect("cache k aliased during append");
        for h in 0..self.heads {
            kd[h * cap * dh + at * dh..h * cap * dh + (at + 1) * dh]
                .copy_from_slice(&ksrc[h * dh..(h + 1) * dh]);
        }
        let vsrc = v_row.to_vec_f32();
        let vd = self.vs[layer].f32_mut().expect("cache v aliased during append");
        for h in 0..self.heads {
            vd[h * cap * dh + at * dh..h * cap * dh + (at + 1) * dh]
                .copy_from_slice(&vsrc[h * dh..(h + 1) * dh]);
        }
    }

    /// Advance the logical length after appending all layers.
    pub fn advance(&mut self) {
        assert!(self.len < self.capacity, "cache full at {}", self.len);
        self.len += 1;
    }

    /// Write a chunked-prefill slice's `[h, n, dh]` K/V rows at positions
    /// `len..len+n` for `layer`. Call [`KvCache::advance_by`] once every
    /// layer is appended.
    pub fn append_rows(&mut self, layer: usize, k: &Tensor, v: &Tensor) {
        let n = k.shape()[1];
        assert!(self.len + n <= self.capacity, "slice {}+{n} over capacity {}", self.len, self.capacity);
        let want = [self.heads, n, self.head_dim];
        assert_eq!(k.shape(), &want[..], "append k shape");
        assert_eq!(v.shape(), &want[..], "append v shape");
        let (cap, dh, at) = (self.capacity, self.head_dim, self.len);
        let ksrc = k.to_vec_f32();
        let kd = self.ks[layer].f32_mut().expect("cache k aliased during append");
        for h in 0..self.heads {
            kd[h * cap * dh + at * dh..h * cap * dh + (at + n) * dh]
                .copy_from_slice(&ksrc[h * n * dh..(h + 1) * n * dh]);
        }
        let vsrc = v.to_vec_f32();
        let vd = self.vs[layer].f32_mut().expect("cache v aliased during append");
        for h in 0..self.heads {
            vd[h * cap * dh + at * dh..h * cap * dh + (at + n) * dh]
                .copy_from_slice(&vsrc[h * n * dh..(h + 1) * n * dh]);
        }
    }

    /// Advance the logical length by `n` after a slice append.
    pub fn advance_by(&mut self, n: usize) {
        assert!(self.len + n <= self.capacity, "slice {}+{n} over capacity {}", self.len, self.capacity);
        self.len += n;
    }

    /// Full-capacity K tensor for `layer` — the decode graph's persistent
    /// input (cheap clone of the shared buffer; drop it before the next
    /// append).
    pub fn k_full(&self, layer: usize) -> Tensor {
        self.ks[layer].clone()
    }

    /// Full-capacity V tensor for `layer`.
    pub fn v_full(&self, layer: usize) -> Tensor {
        self.vs[layer].clone()
    }

    /// Zero-copy gather view of the valid K prefix `[h, len, dh]`
    /// (strided across heads) — the incremental-attention kernel's cache
    /// operand.
    pub fn k_view(&self, layer: usize) -> Tensor {
        self.ks[layer].slice_axis(1, 0, self.len)
    }

    /// Zero-copy gather view of the valid V prefix `[h, len, dh]`.
    pub fn v_view(&self, layer: usize) -> Tensor {
        self.vs[layer].slice_axis(1, 0, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::attention::incremental_attention;

    #[test]
    fn seed_append_and_views_roundtrip() {
        let (h, cap, dh) = (2usize, 8usize, 4usize);
        let mut c = KvCache::new(1, h, cap, dh, None);
        assert_eq!(c.capacity_bytes(), 2 * h * cap * dh * 4);
        assert_eq!(c.resident_bytes(), c.capacity_bytes(), "contiguous cache holds full capacity");

        let k0 = Tensor::rand(&[h, cap, dh], 1.0, 1, None);
        let v0 = Tensor::rand(&[h, cap, dh], 1.0, 2, None);
        c.seed(0, &k0, &v0);
        c.set_len(3);
        assert_eq!(c.len(), 3);
        let kv = c.k_view(0);
        assert_eq!(kv.shape(), &[h, 3, dh]);
        // view rows equal the seeded rows
        let want: Vec<f32> = (0..h)
            .flat_map(|hi| k0.slice_axis(0, hi, 1).slice_axis(1, 0, 3).to_vec_f32())
            .collect();
        assert_eq!(kv.to_vec_f32(), want);

        let krow = Tensor::rand(&[h, 1, dh], 1.0, 3, None);
        let vrow = Tensor::rand(&[h, 1, dh], 1.0, 4, None);
        c.append(0, &krow, &vrow);
        c.advance();
        assert_eq!(c.len(), 4);
        // appended row shows up at position 3 of every head
        let kv = c.k_view(0);
        for hi in 0..h {
            let got = kv.slice_axis(0, hi, 1).slice_axis(1, 3, 1).to_vec_f32();
            let want = krow.slice_axis(0, hi, 1).to_vec_f32();
            assert_eq!(got, want, "head {hi}");
        }
    }

    #[test]
    fn tracker_counts_resident_until_drop() {
        let tr = MemoryTracker::new();
        let c = KvCache::new(2, 2, 16, 8, Some(tr.clone()));
        assert_eq!(tr.current(), c.resident_bytes());
        let view = c.k_view(0);
        drop(c);
        // a live view keeps one layer's K buffer alive
        assert_eq!(tr.current(), 2 * 16 * 8 * 4);
        drop(view);
        assert_eq!(tr.current(), 0);
    }

    #[test]
    fn strided_views_feed_incremental_attention() {
        // cache views are non-contiguous (head stride = cap·dh); the
        // kernel must accept them directly.
        let (h, cap, dh, s) = (2usize, 10usize, 4usize, 6usize);
        let mut c = KvCache::new(1, h, cap, dh, None);
        let k0 = Tensor::rand(&[h, cap, dh], 1.0, 7, None);
        let v0 = Tensor::rand(&[h, cap, dh], 1.0, 8, None);
        c.seed(0, &k0, &v0);
        c.set_len(s);
        assert!(!c.k_view(0).is_contiguous());
        let q = Tensor::rand(&[h, 1, dh], 1.0, 9, None);
        let got = incremental_attention(&q, &c.k_view(0), &c.v_view(0), 0.5, None);
        // reference over materialized prefixes
        let kc = c.k_view(0).to_contiguous(None);
        let vc = c.v_view(0).to_contiguous(None);
        let want = incremental_attention(&q, &kc, &vc, 0.5, None);
        let a: Vec<u32> = got.to_vec_f32().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = want.to_vec_f32().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn append_rows_matches_looped_append() {
        // A slice append must leave exactly the bytes n single-row
        // appends would — same rows, same positions, same strides.
        let (h, cap, dh, n) = (2usize, 12usize, 4usize, 5usize);
        let k = Tensor::rand(&[h, n, dh], 1.0, 11, None);
        let v = Tensor::rand(&[h, n, dh], 1.0, 12, None);
        let mut a = KvCache::new(1, h, cap, dh, None);
        a.append_rows(0, &k, &v);
        a.advance_by(n);
        let mut b = KvCache::new(1, h, cap, dh, None);
        for r in 0..n {
            b.append(0, &k.slice_axis(1, r, 1).to_contiguous(None), &v.slice_axis(1, r, 1).to_contiguous(None));
            b.advance();
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.k_full(0).to_vec_f32(), b.k_full(0).to_vec_f32());
        assert_eq!(a.v_full(0).to_vec_f32(), b.v_full(0).to_vec_f32());
        // strided sources (a transposed view) are accepted too
        let mut c = KvCache::new(1, h, cap, dh, None);
        let kt = k.permute(&[0, 1, 2]); // identity permute keeps layout
        c.append_rows(0, &kt, &v);
        c.advance_by(n);
        assert_eq!(c.k_full(0).to_vec_f32(), a.k_full(0).to_vec_f32());
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn append_rows_past_capacity_panics() {
        let mut c = KvCache::new(1, 1, 4, 2, None);
        c.set_len(2);
        let k = Tensor::rand(&[1, 3, 2], 1.0, 1, None);
        let v = Tensor::rand(&[1, 3, 2], 1.0, 2, None);
        c.append_rows(0, &k, &v);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn advance_past_capacity_panics() {
        let mut c = KvCache::new(1, 1, 2, 2, None);
        c.set_len(2);
        c.advance();
    }
}
