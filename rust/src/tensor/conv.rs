//! 2-D convolution (im2col + blocked GEMM) and pooling, NCHW layout.
//!
//! Needed by the UNet evaluation model. im2col is the memory-hungry route
//! on purpose: it reflects how cuDNN-style implicit-GEMM workspace scales
//! with the spatial extent, which is the activation-memory behaviour the
//! paper's UNet experiments exercise.

use super::matmul::matmul;
use super::{MemoryTracker, Tensor};
use crate::util::pool;

/// Core of [`conv2d`]: computes into `out` (length N·Cout·Ho·Wo),
/// returning the output shape. The im2col matrix, the pre-permute GEMM
/// result and any input materialization remain transient workspace on
/// `tracker`.
pub fn conv2d_into(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    pad: usize,
    out: &mut [f32],
    tracker: Option<MemoryTracker>,
) -> Vec<usize> {
    assert_eq!(x.rank(), 4, "conv2d input must be NCHW");
    assert_eq!(w.rank(), 4, "conv2d weight must be OIHW");
    let (n, cin, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (cout, cin2, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    assert_eq!(cin, cin2, "conv2d channel mismatch");
    assert!(stride >= 1);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wd + 2 * pad - kw) / stride + 1;

    let xc = x.to_contiguous(tracker.clone());
    let xv = xc.f32_contiguous();

    // im2col: [N*Ho*Wo, Cin*Kh*Kw] — the workspace that dominates memory.
    // Each output row is independent, so rows partition over the pool.
    let cols_rows = n * ho * wo;
    let cols_width = cin * kh * kw;
    let mut cols = vec![0.0f32; cols_rows * cols_width];
    pool::par_rows(
        &mut cols,
        cols_rows,
        cols_width,
        cols_rows * cols_width,
        |r0, r1, slab| {
            for r in r0..r1 {
                let ni = r / (ho * wo);
                let oy = (r / wo) % ho;
                let ox = r % wo;
                let dst = &mut slab[(r - r0) * cols_width..(r - r0 + 1) * cols_width];
                let mut col_ix = 0usize;
                for ci in 0..cin {
                    let plane = (ni * cin + ci) * h * wd;
                    for ky in 0..kh {
                        let iy = oy as isize * stride as isize + ky as isize - pad as isize;
                        for kx in 0..kw {
                            let ix = ox as isize * stride as isize + kx as isize - pad as isize;
                            dst[col_ix] = if iy >= 0
                                && iy < h as isize
                                && ix >= 0
                                && ix < wd as isize
                            {
                                xv[plane + iy as usize * wd + ix as usize]
                            } else {
                                0.0
                            };
                            col_ix += 1;
                        }
                    }
                }
            }
        },
    );
    let cols_t = Tensor::from_f32(cols, &[cols_rows, cols_width], tracker.clone());

    // weights as [Cout, Cin*Kh*Kw]; out = cols @ w^T → [N*Ho*Wo, Cout]
    let wt = w
        .reshape(&[cout, cols_width], tracker.clone())
        .permute(&[1, 0]);
    let mm = matmul(&cols_t, &wt, tracker.clone()); // [rows, Cout]

    // [N, Ho, Wo, Cout] → [N, Cout, Ho, Wo]
    assert_eq!(out.len(), n * cout * ho * wo, "conv2d_into length mismatch");
    mm.reshape(&[n, ho, wo, cout], tracker)
        .permute(&[0, 3, 1, 2])
        .copy_into_f32(out);
    vec![n, cout, ho, wo]
}

/// `x: [N, Cin, H, W]`, `w: [Cout, Cin, Kh, Kw]` → `[N, Cout, Ho, Wo]`.
/// Symmetric zero padding `pad`, stride `stride`.
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    pad: usize,
    tracker: Option<MemoryTracker>,
) -> Tensor {
    let (h, wd) = (x.shape()[2], x.shape()[3]);
    let (cout, kh, kw) = (w.shape()[0], w.shape()[2], w.shape()[3]);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wd + 2 * pad - kw) / stride + 1;
    let mut out = vec![0.0f32; x.shape()[0] * cout * ho * wo];
    let out_shape = conv2d_into(x, w, stride, pad, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

/// Core of [`avgpool2x_nchw`]: pools into `out`, returning the shape.
pub fn avgpool2x_into(x: &Tensor, out: &mut [f32], tracker: Option<MemoryTracker>) -> Vec<usize> {
    assert_eq!(x.rank(), 4);
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % 2 == 0 && w % 2 == 0, "avgpool2x needs even spatial dims");
    let (oh, ow) = (h / 2, w / 2);
    assert_eq!(out.len(), n * c * oh * ow, "avgpool_into length mismatch");
    let xc = x.to_contiguous(tracker);
    let xv = xc.f32_contiguous();
    // One task per (n, c) plane — planes are disjoint output slabs.
    pool::par_rows(out, n * c, oh * ow, n * c * h * w, |p0, p1, slab| {
        for p in p0..p1 {
            let sbase = p * h * w;
            let plane = &mut slab[(p - p0) * oh * ow..(p - p0 + 1) * oh * ow];
            for y in 0..oh {
                for x2 in 0..ow {
                    let s = sbase + 2 * y * w + 2 * x2;
                    plane[y * ow + x2] =
                        0.25 * (xv[s] + xv[s + 1] + xv[s + w] + xv[s + w + 1]);
                }
            }
        }
    });
    vec![n, c, oh, ow]
}

/// 2×2 average pool, stride 2 (UNet downsampling).
pub fn avgpool2x_nchw(x: &Tensor, tracker: Option<MemoryTracker>) -> Tensor {
    let mut out = vec![0.0f32; x.numel() / 4];
    let out_shape = avgpool2x_into(x, &mut out, tracker.clone());
    Tensor::from_f32(out, &out_shape, tracker)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Direct (quadruple-loop) conv reference.
    fn conv_ref(
        x: &Tensor,
        w: &Tensor,
        stride: usize,
        pad: usize,
    ) -> Vec<f32> {
        let (n, cin, h, wd) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let (cout, _, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
        let ho = (h + 2 * pad - kh) / stride + 1;
        let wo = (wd + 2 * pad - kw) / stride + 1;
        let mut out = vec![0.0f32; n * cout * ho * wo];
        for ni in 0..n {
            for co in 0..cout {
                for oy in 0..ho {
                    for ox in 0..wo {
                        let mut acc = 0.0;
                        for ci in 0..cin {
                            for ky in 0..kh {
                                for kx in 0..kw {
                                    let iy = (oy * stride + ky) as isize - pad as isize;
                                    let ix = (ox * stride + kx) as isize - pad as isize;
                                    if iy >= 0 && iy < h as isize && ix >= 0 && ix < wd as isize {
                                        acc += x.at(&[ni, ci, iy as usize, ix as usize])
                                            * w.at(&[co, ci, ky, kx]);
                                    }
                                }
                            }
                        }
                        out[((ni * cout + co) * ho + oy) * wo + ox] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn identity_kernel() {
        let x = Tensor::rand(&[1, 1, 4, 4], 1.0, 21, None);
        let w = Tensor::from_f32(vec![1.0], &[1, 1, 1, 1], None);
        let y = conv2d(&x, &w, 1, 0, None);
        assert_eq!(y.shape(), x.shape());
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn conv_matches_direct_reference() {
        for &(cin, cout, k, stride, pad) in
            &[(3, 8, 3, 1, 1), (4, 4, 3, 2, 1), (2, 5, 1, 1, 0), (1, 2, 5, 1, 2)]
        {
            let x = Tensor::rand(&[2, cin, 8, 8], 1.0, 31, None);
            let w = Tensor::rand(&[cout, cin, k, k], 0.5, 32, None);
            let got = conv2d(&x, &w, stride, pad, None);
            let want = conv_ref(&x, &w, stride, pad);
            let gv = got.to_vec_f32();
            assert_eq!(gv.len(), want.len());
            for (g, wv) in gv.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-3, "conv mismatch {g} vs {wv}");
            }
        }
    }

    #[test]
    fn conv_shape_math() {
        let x = Tensor::zeros(&[1, 3, 16, 16], None);
        let w = Tensor::zeros(&[8, 3, 3, 3], None);
        assert_eq!(conv2d(&x, &w, 1, 1, None).shape(), &[1, 8, 16, 16]);
        assert_eq!(conv2d(&x, &w, 2, 1, None).shape(), &[1, 8, 8, 8]);
    }

    #[test]
    fn avgpool_halves() {
        let x = Tensor::from_f32(vec![1., 2., 3., 4.], &[1, 1, 2, 2], None);
        let p = avgpool2x_nchw(&x, None);
        assert_eq!(p.shape(), &[1, 1, 1, 1]);
        assert!((p.scalar() - 2.5).abs() < 1e-6);
    }
}
