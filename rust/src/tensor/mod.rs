//! CPU tensor substrate with instrumented allocation tracking.
//!
//! This module is the execution substrate that stands in for the paper's
//! A100/CUDA testbed (DESIGN.md §5): every intermediate buffer registers
//! with a [`MemoryTracker`], so the peak activation memory that AutoChunk
//! optimizes is *measured*, not estimated. Compute kernels are written so
//! the physical effects behind the paper's cost model exist here too:
//!
//! * blocked matmul whose efficiency drops for small tiles → the
//!   *computation density* term (Eq. 9);
//! * stride-aware slice/concat copies → the *dimension stride* term;
//! * per-op dispatch overhead → the *node count* term (Eq. 8).
//!
//! Tensors are cheap-to-clone views (`Arc` buffer + shape/strides/offset).
//! Transpose and slice are zero-copy; kernels materialize contiguous data
//! when they need it, paying the stride-dependent copy cost.

mod alloc;
pub mod attention;
pub mod conv;
pub mod kvcache;
pub mod kvpage;
pub mod layout;
pub mod matmul;
pub mod ops;
pub mod reduce;

pub use alloc::{Arena, ArenaStore, Buffer, MemoryTracker, SlotSpec, SpillStore, Storage};
pub use kvcache::KvCache;
pub use kvpage::{BlockId, BlockPool, BlockTable};

use std::fmt;
use std::sync::Arc;

/// Logical element type.
///
/// Compute is performed in f32/i32; `size_of` drives the byte accounting
/// used both by the tracker and the estimation pass.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Bytes per element.
    pub fn size_of(self) -> usize {
        4
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::I32 => write!(f, "i32"),
        }
    }
}

/// Row-major contiguous strides for `shape` (in elements).
pub fn contiguous_strides(shape: &[usize]) -> Vec<isize> {
    let mut strides = vec![0isize; shape.len()];
    let mut acc = 1isize;
    for (i, &d) in shape.iter().enumerate().rev() {
        strides[i] = acc;
        acc *= d as isize;
    }
    strides
}

/// Number of elements in `shape`.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// An n-dimensional strided view over a reference-counted buffer.
#[derive(Clone)]
pub struct Tensor {
    buf: Arc<Buffer>,
    shape: Vec<usize>,
    /// Element strides. May be zero (broadcast) or permuted (transpose).
    strides: Vec<isize>,
    offset: usize,
    dtype: DType,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor({}{:?}, contig={})",
            self.dtype,
            self.shape,
            self.is_contiguous()
        )
    }
}

impl Tensor {
    // ---------------------------------------------------------------- ctors

    /// Build an f32 tensor from data; `data.len()` must equal `numel(shape)`.
    pub fn from_f32(data: Vec<f32>, shape: &[usize], tracker: Option<MemoryTracker>) -> Tensor {
        assert_eq!(data.len(), numel(shape), "data/shape mismatch");
        let strides = contiguous_strides(shape);
        Tensor {
            buf: Buffer::new(Storage::F32(data), tracker),
            shape: shape.to_vec(),
            strides,
            offset: 0,
            dtype: DType::F32,
        }
    }

    /// Build an i32 tensor from data.
    pub fn from_i32(data: Vec<i32>, shape: &[usize], tracker: Option<MemoryTracker>) -> Tensor {
        assert_eq!(data.len(), numel(shape), "data/shape mismatch");
        let strides = contiguous_strides(shape);
        Tensor {
            buf: Buffer::new(Storage::I32(data), tracker),
            shape: shape.to_vec(),
            strides,
            offset: 0,
            dtype: DType::I32,
        }
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: &[usize], tracker: Option<MemoryTracker>) -> Tensor {
        Tensor::from_f32(vec![0.0; numel(shape)], shape, tracker)
    }

    /// Constant-filled f32 tensor.
    pub fn full(value: f32, shape: &[usize], tracker: Option<MemoryTracker>) -> Tensor {
        Tensor::from_f32(vec![value; numel(shape)], shape, tracker)
    }

    /// `[0, 1, 2, ...]` along `axis`, broadcast over the rest (materialized).
    pub fn iota(shape: &[usize], axis: usize, tracker: Option<MemoryTracker>) -> Tensor {
        let n = numel(shape);
        let strides = contiguous_strides(shape);
        let mut data = vec![0.0f32; n];
        for (i, v) in data.iter_mut().enumerate() {
            let idx = (i as isize / strides[axis]) as usize % shape[axis];
            *v = idx as f32;
        }
        Tensor::from_f32(data, shape, tracker)
    }

    /// Wrap f32 storage acquired from an arena slot as a contiguous
    /// tensor. Dropping the last reference returns the storage to the
    /// slot's cache and releases the planned bytes.
    pub(crate) fn from_arena_f32(
        data: Vec<f32>,
        shape: &[usize],
        arena: &Arena,
        slot: usize,
        tracker: Option<MemoryTracker>,
    ) -> Tensor {
        assert_eq!(data.len(), numel(shape), "arena data/shape mismatch");
        let strides = contiguous_strides(shape);
        Tensor {
            buf: Buffer::new_arena(Storage::F32(data), arena.clone(), slot, tracker),
            shape: shape.to_vec(),
            strides,
            offset: 0,
            dtype: DType::F32,
        }
    }

    /// As [`Tensor::from_arena_f32`] for i32 storage.
    pub(crate) fn from_arena_i32(
        data: Vec<i32>,
        shape: &[usize],
        arena: &Arena,
        slot: usize,
        tracker: Option<MemoryTracker>,
    ) -> Tensor {
        assert_eq!(data.len(), numel(shape), "arena data/shape mismatch");
        let strides = contiguous_strides(shape);
        Tensor {
            buf: Buffer::new_arena(Storage::I32(data), arena.clone(), slot, tracker),
            shape: shape.to_vec(),
            strides,
            offset: 0,
            dtype: DType::I32,
        }
    }

    /// Re-wrap storage taken out of a dying arena tensor (in-place
    /// compute): counters do not move — see [`Buffer::adopt_arena`].
    pub(crate) fn adopt_arena_f32(
        data: Vec<f32>,
        shape: &[usize],
        arena: Arena,
        slot: usize,
        tracker: Option<MemoryTracker>,
    ) -> Tensor {
        assert_eq!(data.len(), numel(shape), "arena data/shape mismatch");
        let strides = contiguous_strides(shape);
        Tensor {
            buf: Buffer::adopt_arena(Storage::F32(data), arena, slot, tracker),
            shape: shape.to_vec(),
            strides,
            offset: 0,
            dtype: DType::F32,
        }
    }

    /// Attempt to take sole ownership of this tensor's arena-backed f32
    /// storage for in-place reuse. Succeeds only when the tensor is the
    /// unique reference to a contiguous, offset-0, arena-slot buffer —
    /// the conditions the memory planner verifies before authorizing an
    /// elementwise op to compute into its dead operand. On failure the
    /// tensor is handed back untouched.
    #[allow(clippy::type_complexity)]
    pub(crate) fn try_take_arena_f32(
        self,
    ) -> Result<(Vec<f32>, Arena, usize, Option<MemoryTracker>), Tensor> {
        if !self.is_contiguous()
            || self.offset != 0
            || self.dtype != DType::F32
            || self.buf.arena_slot().is_none()
        {
            return Err(self);
        }
        let Tensor {
            buf,
            shape,
            strides,
            offset,
            dtype,
        } = self;
        match Arc::try_unwrap(buf) {
            Ok(buffer) => {
                let (storage, arena_slot, tracker) = buffer.take_for_inplace();
                let (arena, slot) = arena_slot.expect("arena backing checked above");
                match storage {
                    Storage::F32(v) => Ok((v, arena, slot, tracker)),
                    Storage::I32(_) => unreachable!("dtype checked above"),
                }
            }
            Err(buf) => Err(Tensor {
                buf,
                shape,
                strides,
                offset,
                dtype,
            }),
        }
    }

    /// Exclusive mutable access to this tensor's f32 storage, available
    /// only when the view is contiguous at offset 0 and this is the sole
    /// live reference to the buffer — the KV-cache append path
    /// ([`kvcache::KvCache`]). Returns `None` while any alias (a decode
    /// step's cache view) is still live.
    pub(crate) fn f32_mut(&mut self) -> Option<&mut [f32]> {
        if !self.is_contiguous() || self.offset != 0 || self.dtype != DType::F32 {
            return None;
        }
        Arc::get_mut(&mut self.buf).map(|b| match &mut b.storage {
            Storage::F32(v) => v.as_mut_slice(),
            Storage::I32(_) => unreachable!("dtype checked above"),
        })
    }

    /// Chaos-harness kernel fault (DESIGN.md §15): overwrite this
    /// tensor's last element with NaN, in place when the storage is
    /// exclusively held, otherwise by rebuilding a poisoned contiguous
    /// copy on `tracker` so accounting stays exact. The tail element
    /// lives in the row downstream consumers read (the last prompt row /
    /// the decode row), which makes the corruption observable.
    pub(crate) fn poison_tail(&mut self, tracker: &MemoryTracker) {
        if self.numel() == 0 || self.dtype != DType::F32 {
            return;
        }
        if let Some(s) = self.f32_mut() {
            let last = s.len() - 1;
            s[last] = f32::NAN;
            return;
        }
        let mut data = self.to_vec_f32();
        let last = data.len() - 1;
        data[last] = f32::NAN;
        let shape = self.shape.clone();
        *self = Tensor::from_f32(data, &shape, Some(tracker.clone()));
    }

    /// Deterministic pseudo-random uniform values in [-scale, scale]
    /// (xorshift; used by models/tests — no external rand crate).
    pub fn rand(shape: &[usize], scale: f32, seed: u64, tracker: Option<MemoryTracker>) -> Tensor {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).max(1);
        let n = numel(shape);
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let u = (state >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
            data.push(((u * 2.0 - 1.0) as f32) * scale);
        }
        Tensor::from_f32(data, shape, tracker)
    }

    // ------------------------------------------------------------ accessors

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn strides(&self) -> &[isize] {
        &self.strides
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Bytes this view would occupy if materialized contiguously.
    pub fn byte_size(&self) -> usize {
        self.numel() * self.dtype.size_of()
    }

    /// The underlying shared buffer — used by kernels on the fast path.
    pub(crate) fn buffer(&self) -> &Arc<Buffer> {
        &self.buf
    }

    pub(crate) fn offset(&self) -> usize {
        self.offset
    }

    /// True if the view is row-major dense over its buffer region.
    pub fn is_contiguous(&self) -> bool {
        self.strides == contiguous_strides(&self.shape)
    }

    /// True if any dimension is broadcast (stride 0 with extent > 1);
    /// materializing such a view would *expand* memory.
    pub fn has_broadcast_stride(&self) -> bool {
        self.strides
            .iter()
            .zip(&self.shape)
            .any(|(&s, &d)| s == 0 && d > 1)
    }

    /// Raw f32 slice; only valid for contiguous views.
    pub fn f32_contiguous(&self) -> &[f32] {
        assert!(self.is_contiguous(), "tensor not contiguous");
        &self.buf.f32()[self.offset..self.offset + self.numel()]
    }

    /// Raw i32 slice; only valid for contiguous views.
    pub fn i32_contiguous(&self) -> &[i32] {
        assert!(self.is_contiguous(), "tensor not contiguous");
        &self.buf.i32()[self.offset..self.offset + self.numel()]
    }

    /// Element at multi-index (f32 tensors).
    pub fn at(&self, index: &[usize]) -> f32 {
        debug_assert_eq!(index.len(), self.rank());
        let mut off = self.offset as isize;
        for (i, &ix) in index.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix as isize * self.strides[i];
        }
        self.buf.f32()[off as usize]
    }

    /// Element at multi-index (i32 tensors).
    pub fn at_i32(&self, index: &[usize]) -> i32 {
        let mut off = self.offset as isize;
        for (i, &ix) in index.iter().enumerate() {
            off += ix as isize * self.strides[i];
        }
        self.buf.i32()[off as usize]
    }

    /// Copy out as a flat row-major Vec<f32> (handles any strides).
    pub fn to_vec_f32(&self) -> Vec<f32> {
        if self.is_contiguous() {
            return self.f32_contiguous().to_vec();
        }
        let mut out = Vec::with_capacity(self.numel());
        let src = self.buf.f32();
        self.for_each_offset(|off| out.push(src[off]));
        out
    }

    /// Copy out as a flat row-major Vec<i32>.
    pub fn to_vec_i32(&self) -> Vec<i32> {
        if self.is_contiguous() {
            return self.i32_contiguous().to_vec();
        }
        let mut out = Vec::with_capacity(self.numel());
        let src = self.buf.i32();
        self.for_each_offset(|off| out.push(src[off]));
        out
    }

    /// Visit buffer offsets of every element in row-major logical order.
    /// The innermost dimension is iterated in a tight loop so the cost of
    /// strided traversal is proportional to how "broken up" the view is —
    /// this is the physical basis of the stride term in chunk selection.
    pub(crate) fn for_each_offset(&self, mut f: impl FnMut(usize)) {
        if self.rank() == 0 {
            f(self.offset);
            return;
        }
        let inner_dim = self.rank() - 1;
        let inner_n = self.shape[inner_dim];
        let inner_s = self.strides[inner_dim];
        let outer_count: usize = self.shape[..inner_dim].iter().product();
        let mut idx = vec![0usize; inner_dim];
        for _ in 0..outer_count.max(1) {
            let mut base = self.offset as isize;
            for (i, &ix) in idx.iter().enumerate() {
                base += ix as isize * self.strides[i];
            }
            let mut off = base;
            for _ in 0..inner_n {
                f(off as usize);
                off += inner_s;
            }
            // increment odometer
            for i in (0..inner_dim).rev() {
                idx[i] += 1;
                if idx[i] < self.shape[i] {
                    break;
                }
                idx[i] = 0;
            }
        }
    }

    /// Write this view's elements in row-major logical order into `out`
    /// (f32). The arena executor uses this to materialize reshapes,
    /// converts, and permuted copies directly into planned slots.
    pub fn copy_into_f32(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.numel(), "copy_into length mismatch");
        if self.is_contiguous() {
            out.copy_from_slice(self.f32_contiguous());
            return;
        }
        let src = self.buf.f32();
        let mut i = 0usize;
        self.for_each_offset(|off| {
            out[i] = src[off];
            i += 1;
        });
    }

    /// As [`Tensor::copy_into_f32`] for i32 tensors.
    pub fn copy_into_i32(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.numel(), "copy_into length mismatch");
        if self.is_contiguous() {
            out.copy_from_slice(self.i32_contiguous());
            return;
        }
        let src = self.buf.i32();
        let mut i = 0usize;
        self.for_each_offset(|off| {
            out[i] = src[off];
            i += 1;
        });
    }

    /// Materialize the view as a contiguous tensor on `tracker`.
    /// No-op (cheap clone) when already contiguous.
    pub fn to_contiguous(&self, tracker: Option<MemoryTracker>) -> Tensor {
        if self.is_contiguous() {
            return self.clone();
        }
        match self.dtype {
            DType::F32 => Tensor::from_f32(self.to_vec_f32(), &self.shape, tracker),
            DType::I32 => Tensor::from_i32(self.to_vec_i32(), &self.shape, tracker),
        }
    }

    // ------------------------------------------------------------ view ops

    /// Zero-copy axis permutation.
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.rank(), "perm rank mismatch");
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!(!seen[p], "perm has duplicates");
            seen[p] = true;
        }
        let shape = perm.iter().map(|&p| self.shape[p]).collect();
        let strides = perm.iter().map(|&p| self.strides[p]).collect();
        Tensor {
            buf: Arc::clone(&self.buf),
            shape,
            strides,
            offset: self.offset,
            dtype: self.dtype,
        }
    }

    /// Zero-copy slice `[start, start+len)` along `axis`.
    pub fn slice_axis(&self, axis: usize, start: usize, len: usize) -> Tensor {
        assert!(axis < self.rank(), "axis out of range");
        assert!(start + len <= self.shape[axis], "slice out of range");
        let mut shape = self.shape.clone();
        shape[axis] = len;
        Tensor {
            buf: Arc::clone(&self.buf),
            shape,
            strides: self.strides.clone(),
            offset: (self.offset as isize + start as isize * self.strides[axis]) as usize,
            dtype: self.dtype,
        }
    }

    /// Reshape. Zero-copy when contiguous; otherwise materializes first
    /// (the copy lands on `tracker`).
    pub fn reshape(&self, new_shape: &[usize], tracker: Option<MemoryTracker>) -> Tensor {
        assert_eq!(
            numel(new_shape),
            self.numel(),
            "reshape numel mismatch {:?} -> {:?}",
            self.shape,
            new_shape
        );
        let base = if self.is_contiguous() {
            self.clone()
        } else {
            self.to_contiguous(tracker)
        };
        Tensor {
            buf: base.buf,
            shape: new_shape.to_vec(),
            strides: contiguous_strides(new_shape),
            offset: base.offset,
            dtype: base.dtype,
        }
    }

    /// Zero-copy broadcast to `target` shape (numpy rules; broadcast dims get
    /// stride 0). Panics if incompatible.
    pub fn broadcast_to(&self, target: &[usize]) -> Tensor {
        assert!(target.len() >= self.rank(), "cannot broadcast down");
        let pad = target.len() - self.rank();
        let mut strides = vec![0isize; target.len()];
        for i in 0..target.len() {
            if i < pad {
                strides[i] = 0;
            } else {
                let s = self.shape[i - pad];
                if s == target[i] {
                    strides[i] = self.strides[i - pad];
                } else if s == 1 {
                    strides[i] = 0;
                } else {
                    panic!("cannot broadcast {:?} to {:?}", self.shape, target);
                }
            }
        }
        Tensor {
            buf: Arc::clone(&self.buf),
            shape: target.to_vec(),
            strides,
            offset: self.offset,
            dtype: self.dtype,
        }
    }

    /// Scalar extraction for rank-0 / single-element tensors.
    pub fn scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "not a scalar");
        match &self.buf.storage {
            Storage::F32(v) => v[self.offset],
            Storage::I32(v) => v[self.offset] as f32,
        }
    }

    /// Max |a-b| over two same-shaped tensors (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let a = self.to_vec_f32();
        let b = other.to_vec_f32();
        a.iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }
}

/// Broadcast two shapes (numpy rules). Returns the result shape.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Vec<usize> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i + a.len() >= rank { a[i + a.len() - rank] } else { 1 };
        let db = if i + b.len() >= rank { b[i + b.len() - rank] } else { 1 };
        assert!(
            da == db || da == 1 || db == 1,
            "incompatible broadcast {:?} vs {:?}",
            a,
            b
        );
        out[i] = da.max(db);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_strides_row_major() {
        assert_eq!(contiguous_strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(contiguous_strides(&[5]), vec![1]);
        assert!(contiguous_strides(&[]).is_empty());
    }

    #[test]
    fn permute_is_zero_copy_and_correct() {
        let t = Tensor::from_f32((0..6).map(|x| x as f32).collect(), &[2, 3], None);
        let p = t.permute(&[1, 0]);
        assert_eq!(p.shape(), &[3, 2]);
        assert_eq!(p.at(&[0, 1]), t.at(&[1, 0]));
        assert_eq!(p.to_vec_f32(), vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn slice_axis_views_subrange() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[3, 4], None);
        let s = t.slice_axis(0, 1, 2);
        assert_eq!(s.shape(), &[2, 4]);
        assert_eq!(s.to_vec_f32(), (4..12).map(|x| x as f32).collect::<Vec<_>>());
        let s2 = t.slice_axis(1, 2, 2);
        assert_eq!(s2.to_vec_f32(), vec![2., 3., 6., 7., 10., 11.]);
        assert!(!s2.is_contiguous());
    }

    #[test]
    fn reshape_contiguous_zero_copy() {
        let tr = MemoryTracker::new();
        let t = Tensor::from_f32(vec![1.0; 24], &[2, 3, 4], Some(tr.clone()));
        let before = tr.alloc_count();
        let r = t.reshape(&[6, 4], None);
        assert_eq!(tr.alloc_count(), before, "no new allocation");
        assert_eq!(r.shape(), &[6, 4]);
    }

    #[test]
    fn reshape_noncontiguous_materializes() {
        let t = Tensor::from_f32((0..6).map(|x| x as f32).collect(), &[2, 3], None);
        let p = t.permute(&[1, 0]);
        let r = p.reshape(&[6], None);
        assert_eq!(r.to_vec_f32(), vec![0., 3., 1., 4., 2., 5.]);
    }

    #[test]
    fn broadcast_to_stride_zero() {
        let t = Tensor::from_f32(vec![1., 2., 3.], &[3], None);
        let b = t.broadcast_to(&[2, 3]);
        assert_eq!(b.to_vec_f32(), vec![1., 2., 3., 1., 2., 3.]);
        let t2 = Tensor::from_f32(vec![5.], &[1], None);
        let b2 = t2.broadcast_to(&[4]);
        assert_eq!(b2.to_vec_f32(), vec![5.; 4]);
    }

    #[test]
    fn broadcast_shapes_rules() {
        assert_eq!(broadcast_shapes(&[2, 1, 4], &[3, 1]), vec![2, 3, 4]);
        assert_eq!(broadcast_shapes(&[], &[2, 2]), vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "incompatible broadcast")]
    fn broadcast_shapes_incompatible() {
        broadcast_shapes(&[2, 3], &[4]);
    }

    #[test]
    fn iota_values() {
        let t = Tensor::iota(&[2, 3], 1, None);
        assert_eq!(t.to_vec_f32(), vec![0., 1., 2., 0., 1., 2.]);
        let t0 = Tensor::iota(&[2, 3], 0, None);
        assert_eq!(t0.to_vec_f32(), vec![0., 0., 0., 1., 1., 1.]);
    }

    #[test]
    fn rand_deterministic() {
        let a = Tensor::rand(&[16], 1.0, 42, None);
        let b = Tensor::rand(&[16], 1.0, 42, None);
        assert_eq!(a.to_vec_f32(), b.to_vec_f32());
        let c = Tensor::rand(&[16], 1.0, 43, None);
        assert_ne!(a.to_vec_f32(), c.to_vec_f32());
        assert!(a.to_vec_f32().iter().all(|v| v.abs() <= 1.0));
    }

    #[test]
    fn tracked_view_lifecycle() {
        let tr = MemoryTracker::new();
        let t = Tensor::from_f32(vec![0.0; 100], &[100], Some(tr.clone()));
        let view = t.slice_axis(0, 0, 10);
        drop(t);
        // Buffer alive through the view.
        assert_eq!(tr.current(), 400);
        drop(view);
        assert_eq!(tr.current(), 0);
    }
}
