//! AutoChunk: automated activation chunking for memory-efficient
//! long-sequence inference.
//!
//! A three-layer Rust + JAX + Pallas reproduction of Zhao et al., 2024.
//! See DESIGN.md for the architecture and EXPERIMENTS.md for results.
//!
//! Quick tour:
//! * [`ir`] — the operator-graph IR (the FX analogue);
//! * [`passes`] — estimation, chunk search, chunk selection;
//!   [`passes::autochunk`] is the `autochunk(model, budget)` entry point;
//! * [`plan`] — chunk plans and the chunked executor;
//! * [`exec`] — the baseline interpreter with measured peak memory;
//! * [`tensor`] — the instrumented CPU tensor substrate;
//! * [`models`] — the four evaluation models (GPT, ViT, Evoformer, UNet);
//! * [`runtime`] — PJRT loading/execution of JAX AOT artifacts (behind
//!   the `pjrt` feature; stubbed offline);
//! * [`coordinator`] — the serving stack (router, batcher, scheduler);
//! * [`util`] — the scoped worker pool behind all kernel/chunk/search
//!   parallelism (`AUTOCHUNK_THREADS`; DESIGN.md §4), the internal
//!   error type, and the bench timer.
pub mod coordinator;
pub mod exec;
pub mod hlo;
pub mod ir;
pub mod models;
pub mod passes;
pub mod plan;
pub mod runtime;
pub mod tensor;
pub mod util;
