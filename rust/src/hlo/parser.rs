//! The line-oriented HLO text parser.

use crate::ir::{Graph, Node, NodeId, Op};
use crate::tensor::ops::{BinaryOp, UnaryOp};
use crate::tensor::reduce::ReduceOp;
use crate::tensor::DType;
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};
use std::collections::HashMap;

/// Parse an HLO-text module file into a [`Graph`].
pub fn parse_hlo_file<P: AsRef<std::path::Path>>(path: P) -> Result<Graph> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_hlo_text(&text)
}

/// Parse an HLO-text module into a [`Graph`] (ENTRY computation only;
/// nested computations resolve reduce combiners).
pub fn parse_hlo_text(text: &str) -> Result<Graph> {
    // 1. split computations
    let mut combiners: HashMap<String, ReduceOp> = HashMap::new();
    let mut entry_lines: Vec<&str> = Vec::new();
    let mut in_entry = false;
    let mut cur_region: Option<String> = None;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with("HloModule") {
            continue;
        }
        if let Some(rest) = trimmed.strip_suffix('{') {
            let name = rest.trim();
            if let Some(name) = name.strip_prefix("ENTRY ") {
                let _ = name;
                in_entry = true;
            } else {
                cur_region = Some(name.split_whitespace().next().unwrap_or("").to_string());
            }
            continue;
        }
        if trimmed == "}" {
            in_entry = false;
            cur_region = None;
            continue;
        }
        if in_entry {
            entry_lines.push(trimmed);
        } else if let Some(region) = &cur_region {
            // resolve the combiner from the region's ROOT op
            if trimmed.starts_with("ROOT") {
                let op = if trimmed.contains(" add(") {
                    Some(ReduceOp::Sum)
                } else if trimmed.contains(" maximum(") {
                    Some(ReduceOp::Max)
                } else if trimmed.contains(" minimum(") {
                    Some(ReduceOp::Min)
                } else {
                    None
                };
                if let Some(op) = op {
                    combiners.insert(region.clone(), op);
                }
            }
        }
    }
    if entry_lines.is_empty() {
        bail!("no ENTRY computation found");
    }

    // 2. build nodes
    let mut graph = Graph {
        name: "hlo_import".into(),
        ..Default::default()
    };
    let mut by_name: HashMap<String, NodeId> = HashMap::new();
    let mut root: Option<Vec<NodeId>> = None;

    for line in entry_lines {
        let inst = InstLine::parse(line)?;
        if inst.opcode == "tuple" {
            let ids = inst
                .operands
                .iter()
                .map(|o| lookup(&by_name, o))
                .collect::<Result<Vec<_>>>()?;
            if inst.is_root {
                root = Some(ids);
            } else {
                bail!("non-ROOT tuple unsupported");
            }
            continue;
        }
        let (shape, dtype) = parse_shape_type(&inst.ty)
            .ok_or_else(|| anyhow!("unsupported type '{}' in: {}", inst.ty, line))?;
        let ids: Vec<NodeId> = inst
            .operands
            .iter()
            .map(|o| lookup(&by_name, o))
            .collect::<Result<Vec<_>>>()?;

        let id = emit(&mut graph, &inst, shape, dtype, ids, &combiners)?;
        by_name.insert(inst.name.clone(), id);
        if inst.is_root {
            root = Some(vec![id]);
        }
    }

    graph.outputs = root.ok_or_else(|| anyhow!("no ROOT in ENTRY"))?;
    graph
        .validate()
        .map_err(|e| anyhow!("imported graph invalid: {e}"))?;
    Ok(graph)
}

fn lookup(by_name: &HashMap<String, NodeId>, name: &str) -> Result<NodeId> {
    by_name
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("unknown operand '{name}'"))
}

/// One parsed instruction line.
struct InstLine {
    is_root: bool,
    name: String,
    ty: String,
    opcode: String,
    operands: Vec<String>,
    attrs: String,
}

impl InstLine {
    /// `[ROOT] name = ty opcode(op1, op2), attr=..., attr=...`
    fn parse(line: &str) -> Result<InstLine> {
        let (lhs, rhs) = line
            .split_once(" = ")
            .ok_or_else(|| anyhow!("no '=' in instruction: {line}"))?;
        let (is_root, name) = match lhs.trim().strip_prefix("ROOT ") {
            Some(n) => (true, n.trim().to_string()),
            None => (false, lhs.trim().to_string()),
        };
        // rhs = `f32[8,16]{1,0} dot(a, b), attrs...`
        let (ty, rest) = rhs
            .split_once(' ')
            .ok_or_else(|| anyhow!("no type in: {line}"))?;
        let open = rest
            .find('(')
            .ok_or_else(|| anyhow!("no '(' in: {line}"))?;
        let opcode = rest[..open].to_string();
        let close = find_matching_paren(rest, open)
            .ok_or_else(|| anyhow!("unbalanced parens in: {line}"))?;
        let args = &rest[open + 1..close];
        let attrs = rest[close + 1..].trim_start_matches(',').trim().to_string();
        // constants carry values, not operand names
        let operands = if opcode == "constant" || opcode == "iota" || opcode == "parameter" {
            Vec::new()
        } else {
            args.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        };
        let attrs = if opcode == "constant" || opcode == "parameter" {
            args.to_string() // value / index payload
        } else {
            attrs
        };
        Ok(InstLine {
            is_root,
            name,
            ty: ty.to_string(),
            opcode,
            operands,
            attrs,
        })
    }
}

fn find_matching_paren(s: &str, open: usize) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// `f32[8,16]{1,0}` → (shape, dtype). Tuples and unknown types → None.
fn parse_shape_type(ty: &str) -> Option<(Vec<usize>, DType)> {
    let (dt, rest) = if let Some(r) = ty.strip_prefix("f32") {
        (DType::F32, r)
    } else if let Some(r) = ty.strip_prefix("s32") {
        (DType::I32, r)
    } else if let Some(r) = ty.strip_prefix("pred") {
        (DType::F32, r)
    } else if let Some(r) = ty.strip_prefix("f64") {
        (DType::F32, r)
    } else if let Some(r) = ty.strip_prefix("s64") {
        (DType::I32, r)
    } else {
        return None;
    };
    let rest = rest.strip_prefix('[')?;
    let close = rest.find(']')?;
    let dims = &rest[..close];
    let shape = if dims.is_empty() {
        Vec::new()
    } else {
        dims.split(',')
            .map(|d| d.trim().parse::<usize>().ok())
            .collect::<Option<Vec<_>>>()?
    };
    Some((shape, dt))
}

/// `key={a,b,c}` attribute → Vec<usize>.
fn attr_dims(attrs: &str, key: &str) -> Option<Vec<usize>> {
    let pat = format!("{key}={{");
    let start = attrs.find(&pat)? + pat.len();
    let end = attrs[start..].find('}')? + start;
    let body = &attrs[start..end];
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',')
        .map(|d| d.trim().parse::<usize>().ok())
        .collect()
}

/// `key=value` (unbraced) attribute.
fn attr_str<'a>(attrs: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("{key}=");
    let start = attrs.find(&pat)? + pat.len();
    let end = attrs[start..]
        .find([',', ' '])
        .map(|e| e + start)
        .unwrap_or(attrs.len());
    Some(&attrs[start..end])
}

#[allow(clippy::too_many_arguments)]
fn emit(
    graph: &mut Graph,
    inst: &InstLine,
    shape: Vec<usize>,
    dtype: DType,
    mut inputs: Vec<NodeId>,
    combiners: &HashMap<String, ReduceOp>,
) -> Result<NodeId> {
    let opaque = |kind: &str| Op::Opaque { kind: kind.to_string() };
    fn in_shape(g: &Graph, inputs: &[NodeId], i: usize) -> Vec<usize> {
        g.node(inputs[i]).shape.clone()
    }

    let op = match inst.opcode.as_str() {
        "parameter" => {
            if dtype == DType::I32 {
                Op::Input
            } else {
                Op::Param
            }
        }
        "constant" => {
            if shape.is_empty() {
                let v = inst
                    .attrs
                    .trim()
                    .trim_matches(|c| c == '{' || c == '}')
                    .parse::<f32>()
                    .unwrap_or(0.0);
                Op::Const(v)
            } else {
                // array constant: a non-chunkable leaf (analysis-only)
                Op::Param
            }
        }
        "iota" => {
            let axis = attr_str(&inst.attrs, "iota_dimension")
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            Op::Iota { axis }
        }
        "add" => Op::Binary(BinaryOp::Add),
        "subtract" => Op::Binary(BinaryOp::Sub),
        "multiply" => Op::Binary(BinaryOp::Mul),
        "divide" => Op::Binary(BinaryOp::Div),
        "maximum" => Op::Binary(BinaryOp::Max),
        "minimum" => Op::Binary(BinaryOp::Min),
        "power" => Op::Binary(BinaryOp::Pow),
        "exponential" => Op::Unary(UnaryOp::Exp),
        "log" => Op::Unary(UnaryOp::Log),
        "tanh" => Op::Unary(UnaryOp::Tanh),
        "sqrt" => Op::Unary(UnaryOp::Sqrt),
        "rsqrt" => Op::Unary(UnaryOp::Rsqrt),
        "negate" => Op::Unary(UnaryOp::Neg),
        "abs" => Op::Unary(UnaryOp::Abs),
        "logistic" => Op::Unary(UnaryOp::Sigmoid),
        "convert" => Op::Convert,
        "reshape" => Op::Reshape,
        "transpose" => {
            let perm = attr_dims(&inst.attrs, "dimensions")
                .ok_or_else(|| anyhow!("transpose without dimensions"))?;
            Op::Transpose { perm }
        }
        "broadcast" => {
            let dims = attr_dims(&inst.attrs, "dimensions").unwrap_or_default();
            Op::Broadcast { dims }
        }
        "dot" => {
            let lhs_contract = attr_dims(&inst.attrs, "lhs_contracting_dims").unwrap_or_default();
            let rhs_contract = attr_dims(&inst.attrs, "rhs_contracting_dims").unwrap_or_default();
            let lhs_batch = attr_dims(&inst.attrs, "lhs_batch_dims").unwrap_or_default();
            let rhs_batch = attr_dims(&inst.attrs, "rhs_batch_dims").unwrap_or_default();
            Op::DotGeneral {
                lhs_batch,
                rhs_batch,
                lhs_contract,
                rhs_contract,
            }
        }
        "reduce" => {
            if inputs.is_empty() {
                bail!("reduce without operands: {}", inst.name);
            }
            // drop the init-value operand: IR Reduce is single-input
            inputs.truncate(1);
            let dims = attr_dims(&inst.attrs, "dimensions")
                .ok_or_else(|| anyhow!("reduce without dimensions"))?;
            if dims.is_empty() {
                bail!("reduce with empty dimensions: {}", inst.name);
            }
            let region = attr_str(&inst.attrs, "to_apply").unwrap_or("");
            let rop = combiners.get(region).copied().unwrap_or(ReduceOp::Sum);
            if dims.len() == 1 {
                Op::Reduce {
                    op: rop,
                    axis: dims[0],
                    keepdims: false,
                }
            } else {
                // multi-axis reduce: chain single-axis reductions
                let mut cur = inputs[0];
                let mut cur_shape = in_shape(graph, &inputs, 0);
                let mut axes = dims.clone();
                axes.sort_unstable_by(|a, b| b.cmp(a)); // reduce inner first
                for (i, &ax) in axes.iter().enumerate() {
                    if ax >= cur_shape.len() {
                        bail!("reduce axis {ax} out of range in: {}", inst.name);
                    }
                    cur_shape.remove(ax);
                    let id = graph.nodes.len();
                    graph.nodes.push(Node {
                        id,
                        op: Op::Reduce {
                            op: rop,
                            axis: ax,
                            keepdims: false,
                        },
                        inputs: vec![cur],
                        shape: cur_shape.clone(),
                        dtype,
                        name: format!("{}.{}", inst.name, i),
                    });
                    cur = id;
                }
                return Ok(cur);
            }
        }
        "concatenate" => {
            let dims = attr_dims(&inst.attrs, "dimensions")
                .ok_or_else(|| anyhow!("concatenate without dimensions"))?;
            let axis = *dims
                .first()
                .ok_or_else(|| anyhow!("concatenate with empty dimensions: {}", inst.name))?;
            if inputs.is_empty() {
                bail!("concatenate without operands: {}", inst.name);
            }
            Op::Concat { axis }
        }
        "slice" => {
            // slice={[a:b],[c:d],...} — single differing axis supported
            if inputs.is_empty() {
                bail!("slice without operands: {}", inst.name);
            }
            let in_s = in_shape(graph, &inputs, 0);
            let mut op = None;
            if let Some(start_pos) = inst.attrs.find("slice={") {
                let body_start = start_pos + "slice={".len();
                let body_end = inst.attrs[body_start..]
                    .find('}')
                    .map(|e| e + body_start)
                    .unwrap_or(inst.attrs.len());
                let parts: Vec<&str> = inst.attrs[body_start..body_end]
                    .split("],")
                    .collect();
                for (axis, part) in parts.iter().enumerate() {
                    if axis >= in_s.len() {
                        bail!("slice rank mismatch in: {}", inst.name);
                    }
                    let p = part.trim_matches(|c| c == '[' || c == ']');
                    let nums: Vec<usize> = p
                        .split(':')
                        .filter_map(|x| x.parse().ok())
                        .collect();
                    if nums.len() >= 2 {
                        let (start, stop) = (nums[0], nums[1]);
                        let len = stop
                            .checked_sub(start)
                            .ok_or_else(|| anyhow!("slice bounds reversed in: {}", inst.name))?;
                        if len != in_s[axis] {
                            op = Some(Op::Slice { axis, start, len });
                        }
                    }
                }
            }
            op.unwrap_or(Op::Reshape) // full-range slice = identity-ish
        }
        "gather" if inputs.len() >= 2 => {
            // embedding pattern: table [V, D] × i32 ids → [.., D]
            let t = in_shape(graph, &inputs, 0);
            let ids_dt = graph.node(inputs[1]).dtype;
            let offset = attr_dims(&inst.attrs, "offset_dims").unwrap_or_default();
            let collapsed = attr_dims(&inst.attrs, "collapsed_slice_dims").unwrap_or_default();
            if t.len() == 2
                && !shape.is_empty()
                && ids_dt == DType::I32
                && offset == vec![shape.len() - 1]
                && collapsed == vec![0]
            {
                Op::Gather
            } else {
                opaque("gather")
            }
        }
        other => opaque(other),
    };

    // Minimum operand arity per op: malformed/truncated HLO must surface
    // as Err here, never as an out-of-bounds panic in a later pass.
    let min_arity = match &op {
        Op::Input | Op::Param | Op::Const(_) | Op::Iota { .. } | Op::Opaque { .. } => 0,
        Op::Binary(_) | Op::MatMul | Op::DotGeneral { .. } | Op::Gather => 2,
        Op::FusedAttention { .. } => 3,
        _ => 1,
    };
    if inputs.len() < min_arity {
        bail!(
            "{} needs {} operand(s), got {}: {}",
            inst.opcode,
            min_arity,
            inputs.len(),
            inst.name
        );
    }
    if let Op::Transpose { perm } = &op {
        let in_rank = graph.node(inputs[0]).shape.len();
        if perm.len() != in_rank || perm.iter().any(|&p| p >= in_rank) {
            bail!("transpose permutation {perm:?} invalid for rank {in_rank}: {}", inst.name);
        }
    }

    let id = graph.nodes.len();
    match &op {
        Op::Input => graph.inputs.push(id),
        Op::Param => graph.params.push(id),
        _ => {}
    }
    graph.nodes.push(Node {
        id,
        op,
        inputs,
        shape,
        dtype,
        name: inst.name.clone(),
    });
    Ok(id)
}
