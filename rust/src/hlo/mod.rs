//! HLO-text parser: import JAX-lowered artifacts into the graph IR.
//!
//! This is what lets the *same* AutoChunk compiler run over the real AOT
//! path: `python/compile/aot.py` writes `artifacts/*.hlo.txt`, this module
//! parses the ENTRY computation into a [`Graph`], and the passes
//! (estimate/search/select) analyze it exactly like a builder-constructed
//! model. Execution of imported graphs goes through PJRT (`crate::runtime`),
//! not the interpreter — unmodeled ops import as [`Op::Opaque`].
//!
//! Scope: the op set JAX emits for the models in `python/compile/model.py`
//! (elementwise, dot, reshape/transpose/broadcast, reduce, gather-as-
//! embedding, concatenate, slice, iota, convert, constants). Nested
//! computations are resolved only as reduce combiners; `while` bodies
//! (the chunked variants) import as opaque calls.

mod parser;

pub use parser::{parse_hlo_text, parse_hlo_file};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::estimate::estimate;
    use crate::passes::search::{search_chunks, SearchConfig};

    const SAMPLE: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[8,16]{1,0}, f32[16,16]{1,0})->(f32[8,8]{1,0})}

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.2 = f32[] parameter(1)
  ROOT add.3 = f32[] add(Arg_0.2, Arg_1.2)
}

ENTRY main.10 {
  Arg_0.1 = f32[8,16]{1,0} parameter(0)
  Arg_1.1 = f32[16,16]{1,0} parameter(1)
  dot.1 = f32[8,16]{1,0} dot(Arg_0.1, Arg_1.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  transpose.1 = f32[16,8]{0,1} transpose(dot.1), dimensions={1,0}
  dot.2 = f32[8,8]{1,0} dot(dot.1, transpose.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.1 = f32[] constant(0.125)
  broadcast.1 = f32[8,8]{1,0} broadcast(constant.1), dimensions={}
  multiply.1 = f32[8,8]{1,0} multiply(dot.2, broadcast.1)
  reduce.1 = f32[8]{0} reduce(multiply.1, constant.1), dimensions={1}, to_apply=region_0.1
  broadcast.2 = f32[8,8]{1,0} broadcast(reduce.1), dimensions={0}
  subtract.1 = f32[8,8]{1,0} subtract(multiply.1, broadcast.2)
  ROOT tuple.1 = (f32[8,8]{1,0}) tuple(subtract.1)
}
"#;

    #[test]
    fn parses_sample_module() {
        let g = parse_hlo_text(SAMPLE).unwrap();
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        assert_eq!(g.inputs.len() + g.params.len(), 2);
        assert_eq!(g.outputs.len(), 1);
        let out = g.node(g.outputs[0]);
        assert_eq!(out.shape, vec![8, 8]);
    }

    #[test]
    fn dot_becomes_dot_general() {
        let g = parse_hlo_text(SAMPLE).unwrap();
        let dots: Vec<_> = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::ir::Op::DotGeneral { .. }))
            .collect();
        assert_eq!(dots.len(), 2);
        assert_eq!(dots[0].shape, vec![8, 16]);
    }

    #[test]
    fn reduce_combiner_resolved() {
        let g = parse_hlo_text(SAMPLE).unwrap();
        let red = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, crate::ir::Op::Reduce { .. }))
            .unwrap();
        match &red.op {
            crate::ir::Op::Reduce { op, axis, keepdims } => {
                assert_eq!(*op, crate::tensor::reduce::ReduceOp::Sum);
                assert_eq!(*axis, 1);
                assert!(!keepdims);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn broadcast_dims_imported() {
        let g = parse_hlo_text(SAMPLE).unwrap();
        let bs: Vec<_> = g
            .nodes
            .iter()
            .filter_map(|n| match &n.op {
                crate::ir::Op::Broadcast { dims } => Some(dims.clone()),
                _ => None,
            })
            .collect();
        assert!(bs.contains(&vec![]));
        assert!(bs.contains(&vec![0]));
    }

    #[test]
    fn passes_run_on_imported_graph() {
        let g = parse_hlo_text(SAMPLE).unwrap();
        let p = estimate(&g);
        assert!(p.peak_bytes > 0);
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        // the dot.2 scores region admits a row chunk
        assert!(!cands.is_empty(), "no candidates on imported graph");
    }

    #[test]
    fn imports_real_artifact_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/gpt_dense_s64.hlo.txt");
        if !std::path::Path::new(path).exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let g = parse_hlo_file(path).unwrap();
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        assert!(g.len() > 200, "expected a real model, got {} nodes", g.len());
        let p = estimate(&g);
        // peak must be the [4, 64, 64] attention scores neighborhood
        let peak = g.node(p.peak_node);
        assert!(
            peak.shape.iter().product::<usize>() >= 4 * 64 * 64,
            "peak {:?} at {:?}",
            peak.shape,
            peak.op
        );
        let cands = search_chunks(&g, &p, &[], &SearchConfig::default());
        assert!(!cands.is_empty(), "AutoChunk found no chunks in the artifact");
    }
}
