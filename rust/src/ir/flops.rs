//! Per-node FLOP accounting.
//!
//! Feeds the chunk-selection cost function: `N_flop` in the macro term
//! (Eq. 8) and `N_density = FLOPs/node` in the micro term (Eq. 9).

use super::{Graph, NodeId, Op};
use crate::tensor::matmul::matmul_flops;
use crate::tensor::numel;

/// FLOPs attributed to a single node.
///
/// Conventions: elementwise = 1 FLOP/element (GELU etc. counted as a small
/// constant), matmul = 2·M·N·K, softmax = 5/element (max, sub, exp, sum,
/// div), reductions = 1/element, data movement = 0 (it is accounted in the
/// stride term instead, not as compute).
pub fn node_flops(graph: &Graph, id: NodeId) -> u64 {
    let node = graph.node(id);
    let out_n = numel(&node.shape) as u64;
    match &node.op {
        Op::Input | Op::Param | Op::Const(_) | Op::Iota { .. } => 0,
        Op::Binary(_) => out_n,
        Op::Unary(u) => {
            use crate::tensor::ops::UnaryOp::*;
            match u {
                // transcendental-ish ops cost more than 1
                Exp | Log | Tanh | Sigmoid | Gelu | Silu => 8 * out_n,
                Sqrt | Rsqrt => 2 * out_n,
                Neg | Relu | Abs => out_n,
            }
        }
        Op::MatMul => {
            let a = &graph.node(node.inputs[0]).shape;
            let b = &graph.node(node.inputs[1]).shape;
            matmul_flops(a, b)
        }
        Op::DotGeneral {
            lhs_batch,
            lhs_contract,
            ..
        } => {
            let a = &graph.node(node.inputs[0]).shape;
            // out elements × 2 × contracted extent
            let contracted: u64 = lhs_contract.iter().map(|&d| a[d] as u64).product();
            let _ = lhs_batch;
            2 * out_n * contracted
        }
        Op::Reduce { .. } => {
            let in_n = numel(&graph.node(node.inputs[0]).shape) as u64;
            in_n
        }
        Op::Softmax { .. } => 5 * out_n,
        Op::Conv2d { .. } => {
            // out elements × 2 × Cin × Kh × Kw
            let w = &graph.node(node.inputs[1]).shape;
            2 * out_n * (w[1] * w[2] * w[3]) as u64
        }
        Op::AvgPool2x => 4 * out_n,
        Op::FusedAttention { .. } => {
            // 2·sq·skv·d (scores) + 2·sq·skv·dv (weighted sum) + softmax
            let q = &graph.node(node.inputs[0]).shape;
            let k = &graph.node(node.inputs[1]).shape;
            let sq = q[q.len() - 2] as u64;
            let d = q[q.len() - 1] as u64;
            let skv = k[k.len() - 2] as u64;
            let batch = out_n / (sq * node.shape[node.shape.len() - 1] as u64).max(1);
            batch * (4 * sq * skv * d + 5 * sq * skv)
        }
        Op::Opaque { .. } => out_n,
        Op::Gather | Op::Convert | Op::Upsample2x => 0,
        // pure data movement
        Op::Transpose { .. } | Op::Reshape | Op::Broadcast { .. } | Op::Concat { .. } | Op::Slice { .. } => 0,
    }
}

/// Bytes moved by a node (I/O volume): inputs read + output written.
/// Used for roofline-style diagnostics in the perf harness.
pub fn node_bytes(graph: &Graph, id: NodeId) -> u64 {
    let node = graph.node(id);
    let out = node.byte_size() as u64;
    let ins: u64 = node
        .inputs
        .iter()
        .map(|&i| graph.node(i).byte_size() as u64)
        .sum();
    ins + out
}

#[cfg(test)]
mod tests {
    use crate::ir::GraphBuilder;
    use crate::tensor::ops::{BinaryOp, UnaryOp};

    #[test]
    fn matmul_flops_dominate() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[64, 128]);
        let w = b.param("w", &[128, 256]);
        let y = b.matmul(x, w);
        let z = b.unary(UnaryOp::Relu, y);
        let g = b.finish(vec![z]);
        let mm = super::node_flops(&g, y);
        let relu = super::node_flops(&g, z);
        assert_eq!(mm, 2 * 64 * 128 * 256);
        assert_eq!(relu, 64 * 256);
        assert!(mm > 100 * relu);
    }

    #[test]
    fn data_movement_is_free_flops() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 8]);
        let t = b.transpose(x, &[1, 0]);
        let r = b.reshape(t, &[32]);
        let g = b.finish(vec![r]);
        assert_eq!(super::node_flops(&g, t), 0);
        assert_eq!(super::node_flops(&g, r), 0);
    }

    #[test]
    fn total_flops_sums() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[16, 16]);
        let y = b.binary(BinaryOp::Add, x, x);
        let g = b.finish(vec![y]);
        assert_eq!(g.total_flops(), 256);
    }

    #[test]
    fn node_bytes_io_volume() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[16, 16]);
        let y = b.binary(BinaryOp::Add, x, x);
        let g = b.finish(vec![y]);
        // two reads of 1KiB + one write of 1KiB
        assert_eq!(super::node_bytes(&g, y), 3 * 1024);
    }
}
