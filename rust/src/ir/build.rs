//! Programmatic graph construction with shape inference.
//!
//! Every `GraphBuilder` method performs shape/dtype inference and panics on
//! ill-typed graphs at build time — models are static, so this is the
//! equivalent of FX tracing in the paper's PyTorch setting.

use super::{Graph, Node, NodeId, Op};
use crate::tensor::ops::{BinaryOp, UnaryOp};
use crate::tensor::reduce::{reduce_shape, ReduceOp};
use crate::tensor::{broadcast_shapes, DType};

/// Incremental builder; `finish(outputs)` yields the immutable [`Graph`].
pub struct GraphBuilder {
    graph: Graph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder {
            graph: Graph {
                name: name.to_string(),
                ..Default::default()
            },
        }
    }

    fn push(&mut self, op: Op, inputs: Vec<NodeId>, shape: Vec<usize>, dtype: DType, name: String) -> NodeId {
        let id = self.graph.nodes.len();
        for &i in &inputs {
            assert!(i < id, "input {} not yet defined", i);
        }
        self.graph.nodes.push(Node {
            id,
            op,
            inputs,
            shape,
            dtype,
            name,
        });
        id
    }

    fn shape_of(&self, id: NodeId) -> &[usize] {
        &self.graph.nodes[id].shape
    }

    // ----------------------------------------------------------- leaves

    /// Runtime input (f32).
    pub fn input(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.push(Op::Input, vec![], shape.to_vec(), DType::F32, name.into());
        self.graph.inputs.push(id);
        id
    }

    /// Runtime input (i32, e.g. token ids).
    pub fn input_i32(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.push(Op::Input, vec![], shape.to_vec(), DType::I32, name.into());
        self.graph.inputs.push(id);
        id
    }

    /// Runtime input (f32) whose storage persists across executions (KV
    /// cache): excluded from per-run activation accounting, charged as
    /// resident state by the serving tier.
    pub fn input_persistent(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.input(name, shape);
        self.graph.persistent.push(id);
        id
    }

    /// Model parameter (f32), excluded from activation accounting.
    pub fn param(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.push(Op::Param, vec![], shape.to_vec(), DType::F32, name.into());
        self.graph.params.push(id);
        id
    }

    /// Scalar constant.
    pub fn constant(&mut self, value: f32) -> NodeId {
        self.push(Op::Const(value), vec![], vec![], DType::F32, format!("c{value}"))
    }

    /// Iota along `axis` of `shape`.
    pub fn iota(&mut self, shape: &[usize], axis: usize) -> NodeId {
        assert!(axis < shape.len());
        self.push(Op::Iota { axis }, vec![], shape.to_vec(), DType::F32, "iota".into())
    }

    // ------------------------------------------------------ elementwise

    pub fn binary(&mut self, op: BinaryOp, a: NodeId, b: NodeId) -> NodeId {
        let shape = broadcast_shapes(self.shape_of(a), self.shape_of(b));
        self.push(Op::Binary(op), vec![a, b], shape, DType::F32, op.name().into())
    }

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(BinaryOp::Add, a, b)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(BinaryOp::Sub, a, b)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(BinaryOp::Mul, a, b)
    }

    pub fn div(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.binary(BinaryOp::Div, a, b)
    }

    /// `op(a, const)` — materializes the constant + broadcast.
    pub fn binary_scalar(&mut self, op: BinaryOp, a: NodeId, v: f32) -> NodeId {
        let c = self.constant(v);
        let target = self.shape_of(a).to_vec();
        let bc = self.broadcast(c, &target);
        self.binary(op, a, bc)
    }

    pub fn unary(&mut self, op: UnaryOp, a: NodeId) -> NodeId {
        let shape = self.shape_of(a).to_vec();
        self.push(Op::Unary(op), vec![a], shape, DType::F32, op.name().into())
    }

    // -------------------------------------------------------- structure

    /// Batched matmul with batch broadcasting.
    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let (sa, sb) = (self.shape_of(a).to_vec(), self.shape_of(b).to_vec());
        assert!(sa.len() >= 2 && sb.len() >= 2, "matmul rank");
        assert_eq!(
            sa[sa.len() - 1],
            sb[sb.len() - 2],
            "matmul inner dim: {:?} x {:?}",
            sa,
            sb
        );
        let mut shape = broadcast_shapes(&sa[..sa.len() - 2], &sb[..sb.len() - 2]);
        shape.push(sa[sa.len() - 2]);
        shape.push(sb[sb.len() - 1]);
        self.push(Op::MatMul, vec![a, b], shape, DType::F32, "matmul".into())
    }

    pub fn transpose(&mut self, a: NodeId, perm: &[usize]) -> NodeId {
        let sa = self.shape_of(a);
        assert_eq!(perm.len(), sa.len());
        let shape: Vec<usize> = perm.iter().map(|&p| sa[p]).collect();
        self.push(
            Op::Transpose { perm: perm.to_vec() },
            vec![a],
            shape,
            DType::F32,
            "transpose".into(),
        )
    }

    pub fn reshape(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        assert_eq!(
            crate::tensor::numel(self.shape_of(a)),
            crate::tensor::numel(shape),
            "reshape numel mismatch {:?} -> {:?}",
            self.shape_of(a),
            shape
        );
        let dt = self.graph.nodes[a].dtype;
        self.push(Op::Reshape, vec![a], shape.to_vec(), dt, "reshape".into())
    }

    /// Broadcast to `target` using numpy alignment (trailing dims match).
    pub fn broadcast(&mut self, a: NodeId, target: &[usize]) -> NodeId {
        let sa = self.shape_of(a).to_vec();
        let pad = target.len() - sa.len();
        // dims[i]: output dim that input dim i maps to.
        let dims: Vec<usize> = (0..sa.len()).map(|i| i + pad).collect();
        for (i, &d) in dims.iter().enumerate() {
            assert!(
                sa[i] == target[d] || sa[i] == 1,
                "cannot broadcast {:?} to {:?}",
                sa,
                target
            );
        }
        self.push(
            Op::Broadcast { dims },
            vec![a],
            target.to_vec(),
            DType::F32,
            "broadcast".into(),
        )
    }

    pub fn reduce(&mut self, op: ReduceOp, a: NodeId, axis: usize, keepdims: bool) -> NodeId {
        let shape = reduce_shape(self.shape_of(a), axis, keepdims);
        self.push(
            Op::Reduce { op, axis, keepdims },
            vec![a],
            shape,
            DType::F32,
            op.name().into(),
        )
    }

    pub fn softmax(&mut self, a: NodeId, axis: usize) -> NodeId {
        let shape = self.shape_of(a).to_vec();
        assert!(axis < shape.len());
        self.push(Op::Softmax { axis }, vec![a], shape, DType::F32, "softmax".into())
    }

    pub fn concat(&mut self, parts: &[NodeId], axis: usize) -> NodeId {
        assert!(!parts.is_empty());
        let mut shape = self.shape_of(parts[0]).to_vec();
        let mut total = 0;
        for &p in parts {
            let sp = self.shape_of(p);
            assert_eq!(sp.len(), shape.len());
            total += sp[axis];
        }
        shape[axis] = total;
        self.push(
            Op::Concat { axis },
            parts.to_vec(),
            shape,
            DType::F32,
            "concat".into(),
        )
    }

    pub fn slice(&mut self, a: NodeId, axis: usize, start: usize, len: usize) -> NodeId {
        let mut shape = self.shape_of(a).to_vec();
        assert!(start + len <= shape[axis], "slice out of range");
        shape[axis] = len;
        let dt = self.graph.nodes[a].dtype;
        self.push(
            Op::Slice { axis, start, len },
            vec![a],
            shape,
            dt,
            "slice".into(),
        )
    }

    /// Embedding lookup: `table [V,D]` × i32 ids `[..]` → `[.., D]`.
    pub fn gather(&mut self, table: NodeId, ids: NodeId) -> NodeId {
        let ts = self.shape_of(table).to_vec();
        assert_eq!(ts.len(), 2, "gather table must be [V,D]");
        assert_eq!(self.graph.nodes[ids].dtype, DType::I32);
        let mut shape = self.shape_of(ids).to_vec();
        shape.push(ts[1]);
        self.push(Op::Gather, vec![table, ids], shape, DType::F32, "gather".into())
    }

    pub fn conv2d(&mut self, x: NodeId, w: NodeId, stride: usize, pad: usize) -> NodeId {
        let (xs, ws) = (self.shape_of(x).to_vec(), self.shape_of(w).to_vec());
        assert_eq!(xs.len(), 4);
        assert_eq!(ws.len(), 4);
        assert_eq!(xs[1], ws[1], "conv channel mismatch");
        let ho = (xs[2] + 2 * pad - ws[2]) / stride + 1;
        let wo = (xs[3] + 2 * pad - ws[3]) / stride + 1;
        self.push(
            Op::Conv2d { stride, pad },
            vec![x, w],
            vec![xs[0], ws[0], ho, wo],
            DType::F32,
            "conv2d".into(),
        )
    }

    pub fn avgpool2x(&mut self, x: NodeId) -> NodeId {
        let xs = self.shape_of(x).to_vec();
        assert_eq!(xs.len(), 4);
        self.push(
            Op::AvgPool2x,
            vec![x],
            vec![xs[0], xs[1], xs[2] / 2, xs[3] / 2],
            DType::F32,
            "avgpool2x".into(),
        )
    }

    pub fn upsample2x(&mut self, x: NodeId) -> NodeId {
        let xs = self.shape_of(x).to_vec();
        assert_eq!(xs.len(), 4);
        self.push(
            Op::Upsample2x,
            vec![x],
            vec![xs[0], xs[1], xs[2] * 2, xs[3] * 2],
            DType::F32,
            "upsample2x".into(),
        )
    }

    /// Fused memory-efficient attention: `q [..,sq,d]`, `k,v [..,skv,d]`.
    pub fn fused_attention(&mut self, q: NodeId, k: NodeId, v: NodeId, scale: f32) -> NodeId {
        let (qs, ks, vs) = (
            self.shape_of(q).to_vec(),
            self.shape_of(k).to_vec(),
            self.shape_of(v).to_vec(),
        );
        let rank = qs.len();
        assert!(rank >= 2 && ks.len() >= 2 && vs.len() >= 2);
        assert_eq!(qs[rank - 1], ks[ks.len() - 1], "q/k head dim");
        assert_eq!(ks[ks.len() - 2], vs[vs.len() - 2], "k/v rows");
        let mut shape = broadcast_shapes(
            &broadcast_shapes(&qs[..rank - 2], &ks[..ks.len() - 2]),
            &vs[..vs.len() - 2],
        );
        shape.push(qs[rank - 2]);
        shape.push(vs[vs.len() - 1]);
        self.push(
            Op::FusedAttention { scale },
            vec![q, k, v],
            shape,
            DType::F32,
            "fused_attn".into(),
        )
    }

    /// Position-masked fused attention: query row `i` attends key index
    /// `j` iff `j ≤ q_pos[i]`. `q_pos` must be f32 `[sq]`; as a data
    /// input it slices with `q` under chunked execution, keeping chunked
    /// causal prefill bitwise exact.
    pub fn fused_attention_pos(
        &mut self,
        q: NodeId,
        k: NodeId,
        v: NodeId,
        q_pos: NodeId,
        scale: f32,
    ) -> NodeId {
        let (qs, ks, vs) = (
            self.shape_of(q).to_vec(),
            self.shape_of(k).to_vec(),
            self.shape_of(v).to_vec(),
        );
        let rank = qs.len();
        assert!(rank >= 2 && ks.len() >= 2 && vs.len() >= 2);
        assert_eq!(qs[rank - 1], ks[ks.len() - 1], "q/k head dim");
        assert_eq!(ks[ks.len() - 2], vs[vs.len() - 2], "k/v rows");
        let ps = self.shape_of(q_pos).to_vec();
        assert_eq!(ps, vec![qs[rank - 2]], "q_pos must be [sq]");
        assert_eq!(self.graph.nodes[q_pos].dtype, DType::F32, "q_pos must be f32");
        let mut shape = broadcast_shapes(
            &broadcast_shapes(&qs[..rank - 2], &ks[..ks.len() - 2]),
            &vs[..vs.len() - 2],
        );
        shape.push(qs[rank - 2]);
        shape.push(vs[vs.len() - 1]);
        self.push(
            Op::FusedAttention { scale },
            vec![q, k, v, q_pos],
            shape,
            DType::F32,
            "fused_attn".into(),
        )
    }

    pub fn convert_f32(&mut self, a: NodeId) -> NodeId {
        let shape = self.shape_of(a).to_vec();
        self.push(Op::Convert, vec![a], shape, DType::F32, "convert".into())
    }

    /// Rename the most recent node (attach module-path labels in models).
    pub fn label(&mut self, id: NodeId, name: &str) {
        self.graph.nodes[id].name = name.to_string();
    }

    // ------------------------------------------------------- compounds

    /// LayerNorm over the last axis, composed from primitives so the chunk
    /// passes see the real memory profile (mean/var intermediates).
    pub fn layer_norm(&mut self, x: NodeId, gamma: NodeId, beta: NodeId, eps: f32) -> NodeId {
        let rank = self.shape_of(x).len();
        let axis = rank - 1;
        let mean = self.reduce(ReduceOp::Mean, x, axis, true);
        let centered = self.sub(x, mean);
        let sq = self.mul(centered, centered);
        let var = self.reduce(ReduceOp::Mean, sq, axis, true);
        let var_eps = self.binary_scalar(BinaryOp::Add, var, eps);
        let rstd = self.unary(UnaryOp::Rsqrt, var_eps);
        let normed = self.mul(centered, rstd);
        let scaled = self.mul(normed, gamma);
        self.add(scaled, beta)
    }

    /// Linear layer: `x @ w + b` (`w: [in, out]`, `b: [out]`).
    pub fn linear(&mut self, x: NodeId, w: NodeId, b: NodeId) -> NodeId {
        let mm = self.matmul(x, w);
        self.add(mm, b)
    }

    pub fn finish(mut self, outputs: Vec<NodeId>) -> Graph {
        self.graph.outputs = outputs;
        debug_assert!(self.graph.validate().is_ok());
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_shape_inference() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[8, 16, 32]);
        let w = b.param("w", &[32, 64]);
        let y = b.matmul(x, w);
        let g = b.finish(vec![y]);
        assert_eq!(g.node(y).shape, vec![8, 16, 64]);
    }

    #[test]
    #[should_panic(expected = "matmul inner dim")]
    fn matmul_shape_mismatch_panics() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4, 5]);
        let w = b.param("w", &[6, 7]);
        b.matmul(x, w);
    }

    #[test]
    fn layer_norm_compound_shapes() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 8, 16]);
        let g1 = b.param("g", &[16]);
        let beta = b.param("b", &[16]);
        let y = b.layer_norm(x, g1, beta, 1e-5);
        let g = b.finish(vec![y]);
        assert_eq!(g.node(y).shape, vec![2, 8, 16]);
        assert!(g.validate().is_ok());
        // composed of >5 primitive nodes
        assert!(g.len() > 8);
    }

    #[test]
    fn broadcast_dims_mapping() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[16]);
        let y = b.broadcast(x, &[4, 8, 16]);
        let g = b.finish(vec![y]);
        match &g.node(y).op {
            Op::Broadcast { dims } => assert_eq!(dims, &vec![2]),
            _ => panic!(),
        }
    }

    #[test]
    fn concat_shape() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 3]);
        let y = b.input("y", &[2, 5]);
        let c = b.concat(&[x, y], 1);
        let g = b.finish(vec![c]);
        assert_eq!(g.node(c).shape, vec![2, 8]);
    }

    #[test]
    fn gather_shape() {
        let mut b = GraphBuilder::new("t");
        let t = b.param("emb", &[100, 32]);
        let ids = b.input_i32("ids", &[4, 7]);
        let e = b.gather(t, ids);
        let g = b.finish(vec![e]);
        assert_eq!(g.node(e).shape, vec![4, 7, 32]);
    }

    #[test]
    fn conv_pool_upsample_shapes() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 16, 16]);
        let w = b.param("w", &[16, 8, 3, 3]);
        let c = b.conv2d(x, w, 1, 1);
        let p = b.avgpool2x(c);
        let u = b.upsample2x(p);
        let g = b.finish(vec![u]);
        assert_eq!(g.node(c).shape, vec![1, 16, 16, 16]);
        assert_eq!(g.node(p).shape, vec![1, 16, 8, 8]);
        assert_eq!(g.node(u).shape, vec![1, 16, 16, 16]);
    }

    #[test]
    fn inputs_params_recorded_in_order() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2]);
        let w = b.param("w", &[2]);
        let y = b.input("y", &[2]);
        let g = b.finish(vec![x]);
        assert_eq!(g.inputs, vec![x, y]);
        assert_eq!(g.params, vec![w]);
    }
}
