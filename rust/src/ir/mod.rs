//! Graph intermediate representation.
//!
//! The IR plays the role PyTorch FX plays in the paper: a flat, typed,
//! topologically-ordered operator graph over which the AutoChunk passes
//! (estimation → chunk search → chunk selection → codegen) operate.
//!
//! Two producers build this IR:
//! * [`GraphBuilder`] — programmatic model definitions (`crate::models`);
//! * [`crate::hlo`] — the HLO-text parser, importing JAX-lowered artifacts
//!   so the same compiler runs on the real AOT path.

pub mod build;
pub mod flops;

pub use build::GraphBuilder;

use crate::tensor::ops::{BinaryOp, UnaryOp};
use crate::tensor::reduce::ReduceOp;
use crate::tensor::DType;
use std::collections::HashMap;
use std::fmt;

/// Index of a node within its [`Graph`].
pub type NodeId = usize;

/// Operator kind. Shapes/dtypes live on the node, not the op.
#[derive(Clone, PartialEq, Debug)]
pub enum Op {
    /// Runtime input (chunk-search treats it as a leaf).
    Input,
    /// Model parameter (non-chunkable leaf; excluded from activation memory).
    Param,
    /// Scalar or small constant materialized at execution time.
    Const(f32),
    /// `iota` along `axis`.
    Iota { axis: usize },
    /// Elementwise binary op with numpy broadcasting.
    Binary(BinaryOp),
    /// Elementwise unary op.
    Unary(UnaryOp),
    /// Batched matmul `[..,M,K] x [..,K,N]` with batch broadcasting.
    MatMul,
    /// General dot (imported HLO): explicit batch/contracting dims.
    DotGeneral {
        lhs_batch: Vec<usize>,
        rhs_batch: Vec<usize>,
        lhs_contract: Vec<usize>,
        rhs_contract: Vec<usize>,
    },
    /// Axis permutation.
    Transpose { perm: Vec<usize> },
    /// Reshape to the node's `shape`.
    Reshape,
    /// Broadcast to the node's `shape`. `dims[i]` is the output dimension
    /// that input dimension `i` maps to (XLA broadcast_in_dim semantics).
    Broadcast { dims: Vec<usize> },
    /// Single-axis reduction.
    Reduce {
        op: ReduceOp,
        axis: usize,
        keepdims: bool,
    },
    /// Numerically-stable softmax along `axis`.
    Softmax { axis: usize },
    /// Concatenate inputs along `axis`.
    Concat { axis: usize },
    /// Static slice `[start, start+len)` along `axis`.
    Slice {
        axis: usize,
        start: usize,
        len: usize,
    },
    /// Embedding lookup: inputs = (table `[V,D]`, ids i32).
    Gather,
    /// NCHW conv2d with OIHW weights.
    Conv2d { stride: usize, pad: usize },
    /// 2×2 stride-2 average pool.
    AvgPool2x,
    /// Nearest-neighbor 2× upsample.
    Upsample2x,
    /// i32→f32 conversion (or identity for f32).
    Convert,
    /// Fused memory-efficient attention over (q, k, v): never materializes
    /// the score matrix (Rabe & Staats 2022) — the paper's Figure-6
    /// "fused kernel" baseline. An optional 4th input `q_pos [sq]` (f32)
    /// gives each query row its absolute position; key index `j` is
    /// attended iff `j ≤ q_pos[i]` (causal prefill / decode masking —
    /// masked entries are exact no-ops, see `tensor::attention`).
    FusedAttention { scale: f32 },
    /// Unmodeled op from an imported HLO module. Analysis-only: the
    /// estimator charges its output, chunk flows conservatively break at
    /// it, and the interpreter refuses to execute it (imported graphs run
    /// through PJRT, not the interpreter).
    Opaque { kind: String },
}

impl Op {
    /// Short mnemonic for display/profiles.
    pub fn mnemonic(&self) -> String {
        match self {
            Op::Input => "input".into(),
            Op::Param => "param".into(),
            Op::Const(_) => "const".into(),
            Op::Iota { .. } => "iota".into(),
            Op::Binary(b) => b.name().into(),
            Op::Unary(u) => u.name().into(),
            Op::MatMul => "matmul".into(),
            Op::DotGeneral { .. } => "dot_general".into(),
            Op::Transpose { .. } => "transpose".into(),
            Op::Reshape => "reshape".into(),
            Op::Broadcast { .. } => "broadcast".into(),
            Op::Reduce { op, .. } => op.name().into(),
            Op::Softmax { .. } => "softmax".into(),
            Op::Concat { .. } => "concat".into(),
            Op::Slice { .. } => "slice".into(),
            Op::Gather => "gather".into(),
            Op::Conv2d { .. } => "conv2d".into(),
            Op::AvgPool2x => "avgpool2x".into(),
            Op::Upsample2x => "upsample2x".into(),
            Op::Convert => "convert".into(),
            Op::FusedAttention { .. } => "fused_attn".into(),
            Op::Opaque { kind } => format!("opaque:{kind}"),
        }
    }

    /// Leaves hold no computation and are never part of a chunk region body.
    pub fn is_leaf(&self) -> bool {
        matches!(self, Op::Input | Op::Param | Op::Const(_) | Op::Iota { .. })
    }
}

/// A single operator instance.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
    pub inputs: Vec<NodeId>,
    /// Output shape (single output per node).
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// Human-readable label (module path in models, HLO name on import).
    pub name: String,
}

impl Node {
    /// Bytes of this node's output if materialized.
    pub fn byte_size(&self) -> usize {
        crate::tensor::numel(&self.shape) * self.dtype.size_of()
    }
}

/// A flat, topologically-ordered operator graph.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Runtime inputs in positional order.
    pub inputs: Vec<NodeId>,
    /// Parameters in positional order.
    pub params: Vec<NodeId>,
    /// Graph outputs in positional order.
    pub outputs: Vec<NodeId>,
    /// Inputs whose storage persists *across* executions (KV caches):
    /// excluded from per-run activation accounting — the estimator and
    /// memory planner treat them like parameters — while the serving tier
    /// charges their bytes as resident state (DESIGN.md §13).
    pub persistent: Vec<NodeId>,
    /// Optional model name for diagnostics.
    pub name: String,
}

impl Graph {
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// True if `id` is an input marked persistent-across-executions.
    pub fn is_persistent(&self, id: NodeId) -> bool {
        self.persistent.contains(&id)
    }

    /// Total bytes of persistent inputs (the serving tier's resident
    /// charge for one bound cache set).
    pub fn persistent_bytes(&self) -> usize {
        self.persistent.iter().map(|&i| self.node(i).byte_size()).sum()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Consumers of each node (computed on demand).
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                users[i].push(n.id);
            }
        }
        users
    }

    /// Nodes are stored in topological order by construction; verify it.
    /// Returns an error string naming the first violation (test/debug aid).
    pub fn validate(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {} has id {}", i, n.id));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(format!(
                        "node {} ({}) uses forward reference {}",
                        i,
                        n.name,
                        inp
                    ));
                }
            }
            if n.shape.iter().any(|&d| d == 0) {
                return Err(format!("node {} ({}) has zero dim", i, n.name));
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(format!("output {} out of range", o));
            }
        }
        for &p in &self.persistent {
            if !self.inputs.contains(&p) {
                return Err(format!("persistent node {} is not an input", p));
            }
        }
        Ok(())
    }

    /// Total FLOPs of the graph (Σ per-node; see [`flops::node_flops`]).
    pub fn total_flops(&self) -> u64 {
        self.nodes.iter().map(|n| flops::node_flops(self, n.id)).sum()
    }

    /// Map from node name to id (HLO import / debugging).
    pub fn name_index(&self) -> HashMap<String, NodeId> {
        self.nodes
            .iter()
            .map(|n| (n.name.clone(), n.id))
            .collect()
    }
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "graph {} ({} nodes)", self.name, self.nodes.len())?;
        for n in &self.nodes {
            writeln!(
                f,
                "  %{:<4} = {:<12} {:?}{:<20} <- {:?}  # {}",
                n.id,
                n.op.mnemonic(),
                n.dtype,
                format!("{:?}", n.shape),
                n.inputs,
                n.name
            )?;
        }
        writeln!(f, "  outputs: {:?}", self.outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::GraphBuilder;

    #[test]
    fn validate_catches_forward_reference() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2, 2]);
        let y = b.unary(crate::tensor::ops::UnaryOp::Relu, x);
        let mut g = b.finish(vec![y]);
        assert!(g.validate().is_ok());
        g.nodes[1].inputs = vec![1]; // self-reference
        assert!(g.validate().is_err());
    }

    #[test]
    fn users_map() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[4]);
        let a = b.unary(crate::tensor::ops::UnaryOp::Relu, x);
        let c = b.binary(crate::tensor::ops::BinaryOp::Add, a, x);
        let g = b.finish(vec![c]);
        let users = g.users();
        assert_eq!(users[x], vec![a, c]);
        assert_eq!(users[a], vec![c]);
        assert!(users[c].is_empty());
    }

    #[test]
    fn display_smoke() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[2]);
        let g = b.finish(vec![x]);
        assert!(format!("{g}").contains("input"));
    }
}
