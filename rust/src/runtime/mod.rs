//! PJRT runtime: load and execute AOT artifacts from Rust.
//!
//! `python/compile/aot.py` runs once (`make artifacts`) and writes
//! `artifacts/<tag>.hlo.txt` + `<tag>.meta` + parameter blobs; this module
//! scans the directory, compiles the HLO text on the PJRT CPU client
//! (`xla` crate; text interchange per /opt/xla-example/README.md), and
//! executes variants from the serving hot path. Python is never invoked.
//!
//! The `xla` crate is an *external* dependency the offline build cannot
//! fetch, so everything touching it sits behind the off-by-default `pjrt`
//! cargo feature (DESIGN.md §6). Without it the [`Registry`] scan,
//! admission control, and wave planning still work; only `run`/`run_f32`
//! report an error.

mod registry;

pub use registry::{ArtifactMeta, Registry};

#[cfg(feature = "pjrt")]
mod params;
#[cfg(feature = "pjrt")]
pub use params::ParamSet;

#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::{ArtifactMeta, ParamSet, Registry};
    use crate::util::error::{Context, Result};
    use std::collections::HashMap;

    /// A compiled model variant ready to execute.
    pub struct LoadedModel {
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Lazily-loading runtime over an artifact directory.
    pub struct Runtime {
        client: xla::PjRtClient,
        registry: Registry,
        params: HashMap<(String, usize), ParamSet>, // by (model, seq bucket)
        loaded: HashMap<String, LoadedModel>,
    }

    impl Runtime {
        /// Scan `dir` and connect the PJRT CPU client.
        pub fn new(dir: &str) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let registry = Registry::scan(dir)?;
            Ok(Runtime {
                client,
                registry,
                params: HashMap::new(),
                loaded: HashMap::new(),
            })
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        /// Compile (once) and return the variant tagged `tag`.
        pub fn load(&mut self, tag: &str) -> Result<&LoadedModel> {
            if !self.loaded.contains_key(tag) {
                let meta = self
                    .registry
                    .get(tag)
                    .with_context(|| format!("unknown artifact '{tag}'"))?
                    .clone();
                let proto = xla::HloModuleProto::from_text_file(&meta.hlo_path)
                    .with_context(|| format!("parsing {}", meta.hlo_path))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {tag}"))?;
                self.loaded.insert(tag.to_string(), LoadedModel { meta, exe });
            }
            Ok(&self.loaded[tag])
        }

        /// Parameter set for a (model, seq) bucket (loaded once per bucket).
        pub fn params_for(&mut self, model: &str, seq: usize) -> Result<&ParamSet> {
            let key = (model.to_string(), seq);
            if !self.params.contains_key(&key) {
                let ps = ParamSet::load(self.registry.dir(), model, seq)?;
                self.params.insert(key.clone(), ps);
            }
            Ok(&self.params[&key])
        }

        /// Execute variant `tag` on `tokens` (padded/truncated to the bucket).
        /// Returns the hidden-state output row-major. GPT artifacts only.
        pub fn run(&mut self, tag: &str, tokens: &[i32]) -> Result<Vec<f32>> {
            let meta = self
                .registry
                .get(tag)
                .with_context(|| format!("unknown artifact '{tag}'"))?
                .clone();
            let seq = meta.seq;
            let mut toks = tokens.to_vec();
            toks.resize(seq, 0); // pad with token 0 / truncate to bucket
            let tok_lit = xla::Literal::vec1(&toks).reshape(&[seq as i64])?;
            self.run_with_input(&meta, tok_lit)
        }

        /// Execute a ViT-style variant on flat f32 input (padded to the
        /// bucket's `[seq, patch_dim]` shape).
        pub fn run_f32(&mut self, tag: &str, data: &[f32], patch_dim: usize) -> Result<Vec<f32>> {
            let meta = self
                .registry
                .get(tag)
                .with_context(|| format!("unknown artifact '{tag}'"))?
                .clone();
            let want = meta.seq * patch_dim;
            let mut buf = data.to_vec();
            buf.resize(want, 0.0);
            let lit = xla::Literal::vec1(&buf).reshape(&[meta.seq as i64, patch_dim as i64])?;
            self.run_with_input(&meta, lit)
        }

        fn run_with_input(&mut self, meta: &ArtifactMeta, input: xla::Literal) -> Result<Vec<f32>> {
            // make sure params for the bucket are loaded before borrowing exe
            self.params_for(&meta.model, meta.seq)?;
            self.load(&meta.tag)?;
            let params = &self.params[&(meta.model.clone(), meta.seq)];
            let model = &self.loaded[&meta.tag];

            let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + params.literals.len());
            args.push(&input);
            for l in &params.literals {
                args.push(l);
            }
            let result = model.exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::Registry;
    use crate::util::error::Result;

    /// Offline stand-in for the PJRT runtime: registry scanning and the
    /// coordinator's routing/wave-planning paths work; execution errors.
    pub struct Runtime {
        registry: Registry,
    }

    impl Runtime {
        /// Scan `dir`; succeeds whenever the artifact directory parses.
        pub fn new(dir: &str) -> Result<Runtime> {
            Ok(Runtime {
                registry: Registry::scan(dir)?,
            })
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        /// Execution requires the `pjrt` feature.
        pub fn run(&mut self, tag: &str, _tokens: &[i32]) -> Result<Vec<f32>> {
            Err(crate::anyhow!(
                "cannot execute artifact '{tag}': this build lacks the `pjrt` feature \
                 (see DESIGN.md §6)"
            ))
        }

        /// Execution requires the `pjrt` feature.
        pub fn run_f32(&mut self, tag: &str, _data: &[f32], _patch_dim: usize) -> Result<Vec<f32>> {
            Err(crate::anyhow!(
                "cannot execute artifact '{tag}': this build lacks the `pjrt` feature \
                 (see DESIGN.md §6)"
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/gpt_dense_s64.hlo.txt", artifacts_dir())).exists()
    }

    #[test]
    fn registry_scans_artifacts() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let reg = Registry::scan(&artifacts_dir()).unwrap();
        assert!(reg.len() >= 4, "found {}", reg.len());
        let dense = reg.get("gpt_dense_s64").unwrap();
        assert_eq!(dense.seq, 64);
        assert_eq!(dense.mode, "dense");
        assert!(dense.est_activation_bytes > 0);
        // chunked variants must advertise lower activation than dense
        let chunked = reg.get("gpt_chunked_s64_n8").unwrap();
        assert!(chunked.est_activation_bytes < dense.est_activation_bytes);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        if !have_artifacts() {
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let err = rt.run("gpt_dense_s64", &[1, 2, 3]).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn dense_and_chunked_agree_through_pjrt() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let tokens: Vec<i32> = (0..64).map(|i| (i * 7) % 512).collect();
        let dense = rt.run("gpt_dense_s64", &tokens).unwrap();
        let chunked = rt.run("gpt_chunked_s64_n4", &tokens).unwrap();
        let fused = rt.run("gpt_fused_s64", &tokens).unwrap();
        assert_eq!(dense.len(), 64 * 128);
        let d_max = dense
            .iter()
            .zip(&chunked)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d_max < 1e-3, "dense vs chunked diff {d_max}");
        let f_max = dense
            .iter()
            .zip(&fused)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(f_max < 1e-3, "dense vs fused diff {f_max}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn vit_variants_agree_through_pjrt() {
        if !have_artifacts()
            || !std::path::Path::new(&format!("{}/vit_dense_s64.meta", artifacts_dir())).exists()
        {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let patch_dim = 192;
        let data: Vec<f32> = (0..64 * patch_dim).map(|i| ((i % 97) as f32) / 97.0).collect();
        let dense = rt.run_f32("vit_dense_s64", &data, patch_dim).unwrap();
        let fused = rt.run_f32("vit_fused_s64", &data, patch_dim).unwrap();
        let chunked = rt.run_f32("vit_chunked_s64_n4", &data, patch_dim).unwrap();
        assert_eq!(dense.len(), 64); // class logits
        let d1 = dense.iter().zip(&fused).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        let d2 = dense.iter().zip(&chunked).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(d1 < 1e-3, "dense vs fused {d1}");
        assert!(d2 < 1e-3, "dense vs chunked {d2}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn short_request_padded_into_bucket() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        let out = rt.run("gpt_dense_s64", &[1, 2, 3]).unwrap();
        assert_eq!(out.len(), 64 * 128);
        assert!(out.iter().all(|x| x.is_finite()));
    }
}
