//! Artifact registry: scan `artifacts/` and parse `.meta` sidecars.

use crate::anyhow;
use crate::util::error::{Context, Result};
use std::collections::HashMap;

/// Metadata of one AOT artifact (from its `.meta` key=value sidecar).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub tag: String,
    pub hlo_path: String,
    pub model: String,
    pub mode: String, // dense | fused | chunked
    pub seq: usize,
    pub d_model: usize,
    pub heads: usize,
    pub layers: usize,
    pub vocab: usize,
    pub n_chunks: usize,
    pub num_params: usize,
    pub param_names: Vec<String>,
    /// JAX-side analytic estimate of the variant's peak activation bytes;
    /// the coordinator's admission control treats this as the per-request
    /// memory cost.
    pub est_activation_bytes: usize,
    pub output_shape: Vec<usize>,
}

/// All artifacts found in a directory.
#[derive(Debug, Default)]
pub struct Registry {
    dir: String,
    by_tag: HashMap<String, ArtifactMeta>,
}

impl Registry {
    /// Scan `dir` for `*.meta` files.
    pub fn scan(dir: &str) -> Result<Registry> {
        let mut by_tag = HashMap::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("artifact dir {dir} (run `make artifacts`)"))?;
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("meta") {
                continue;
            }
            let tag = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow!("bad meta filename"))?
                .to_string();
            let meta = parse_meta(&tag, dir, &std::fs::read_to_string(&path)?)
                .with_context(|| format!("parsing {}", path.display()))?;
            by_tag.insert(tag, meta);
        }
        Ok(Registry {
            dir: dir.to_string(),
            by_tag,
        })
    }

    /// An empty in-memory registry (no artifact directory). The native
    /// serving engine registers its compiled variants here so routing and
    /// introspection share one catalog with the AOT/PJRT tier.
    pub fn in_memory() -> Registry {
        Registry::default()
    }

    /// Insert (or replace) a variant's metadata. Used by the native
    /// engine's plan cache and by tests that synthesize catalogs without
    /// an artifact directory.
    pub fn register(&mut self, meta: ArtifactMeta) {
        self.by_tag.insert(meta.tag.clone(), meta);
    }

    pub fn dir(&self) -> &str {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.by_tag.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_tag.is_empty()
    }

    pub fn get(&self, tag: &str) -> Option<&ArtifactMeta> {
        self.by_tag.get(tag)
    }

    /// All metas, sorted by tag for deterministic iteration.
    pub fn all(&self) -> Vec<&ArtifactMeta> {
        let mut v: Vec<_> = self.by_tag.values().collect();
        v.sort_by(|a, b| a.tag.cmp(&b.tag));
        v
    }

    /// Sequence buckets available for a model, ascending.
    pub fn buckets(&self, model: &str) -> Vec<usize> {
        let mut seqs: Vec<usize> = self
            .by_tag
            .values()
            .filter(|m| m.model == model)
            .map(|m| m.seq)
            .collect();
        seqs.sort_unstable();
        seqs.dedup();
        seqs
    }

    /// Variants of a model at a bucket, sorted by estimated activation
    /// descending (dense first) — the coordinator walks this list until
    /// one fits the remaining memory budget.
    pub fn variants(&self, model: &str, seq: usize) -> Vec<&ArtifactMeta> {
        let mut v: Vec<_> = self
            .by_tag
            .values()
            .filter(|m| m.model == model && m.seq == seq)
            .collect();
        v.sort_by(|a, b| {
            b.est_activation_bytes
                .cmp(&a.est_activation_bytes)
                .then(a.tag.cmp(&b.tag))
        });
        v
    }
}

fn parse_meta(tag: &str, dir: &str, text: &str) -> Result<ArtifactMeta> {
    let mut kv = HashMap::new();
    for line in text.lines() {
        if let Some((k, v)) = line.split_once('=') {
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    let get = |k: &str| -> Result<&String> {
        kv.get(k).ok_or_else(|| anyhow!("missing key '{k}'"))
    };
    let get_usize = |k: &str| -> Result<usize> {
        get(k)?.parse::<usize>().map_err(|e| anyhow!("{k}: {e}"))
    };
    Ok(ArtifactMeta {
        tag: tag.to_string(),
        hlo_path: format!("{dir}/{tag}.hlo.txt"),
        model: get("model")?.clone(),
        mode: get("mode")?.clone(),
        seq: get_usize("seq")?,
        d_model: get_usize("d_model")?,
        heads: get_usize("heads")?,
        layers: get_usize("layers")?,
        vocab: get_usize("vocab")?,
        n_chunks: get_usize("n_chunks")?,
        num_params: get_usize("num_params")?,
        param_names: get("param_names")?
            .split(',')
            .map(|s| s.to_string())
            .collect(),
        est_activation_bytes: get_usize("est_activation_bytes")?,
        output_shape: get("output_shape")?
            .split('x')
            .map(|s| s.parse::<usize>())
            .collect::<Result<Vec<_>, _>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_roundtrip() {
        let text = "model=gpt\nmode=dense\nseq=64\nd_model=128\nheads=4\nlayers=2\n\
                    vocab=512\nff_mult=4\nn_chunks=1\nnum_params=28\n\
                    param_names=a,b,c\nest_activation_bytes=123456\noutput_shape=64x128\n";
        let m = parse_meta("gpt_dense_s64", "/tmp/a", text).unwrap();
        assert_eq!(m.seq, 64);
        assert_eq!(m.param_names.len(), 3);
        assert_eq!(m.output_shape, vec![64, 128]);
        assert_eq!(m.hlo_path, "/tmp/a/gpt_dense_s64.hlo.txt");
    }

    #[test]
    fn parse_meta_missing_key_errors() {
        assert!(parse_meta("t", "/tmp", "model=gpt\n").is_err());
    }

    #[test]
    fn in_memory_register_and_route() {
        let mut reg = Registry::in_memory();
        assert!(reg.is_empty());
        for (tag, seq, est) in [
            ("gpt_native_s64", 64usize, 1000usize),
            ("gpt_native_s128", 128, 4000),
            ("gpt_native_s128_d1", 128, 2000),
        ] {
            reg.register(ArtifactMeta {
                tag: tag.into(),
                hlo_path: String::new(),
                model: "gpt".into(),
                mode: "native".into(),
                seq,
                d_model: 256,
                heads: 8,
                layers: 4,
                vocab: 8192,
                n_chunks: 1,
                num_params: 0,
                param_names: Vec::new(),
                est_activation_bytes: est,
                output_shape: vec![seq, 256],
            });
        }
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.buckets("gpt"), vec![64, 128]);
        let v = reg.variants("gpt", 128);
        assert_eq!(v.len(), 2);
        assert!(v[0].est_activation_bytes >= v[1].est_activation_bytes);
        // re-register replaces
        let mut m = reg.get("gpt_native_s64").unwrap().clone();
        m.est_activation_bytes = 999;
        reg.register(m);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.get("gpt_native_s64").unwrap().est_activation_bytes, 999);
    }
}
