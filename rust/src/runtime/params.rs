//! Parameter blobs: raw little-endian f32 exported by `aot.py`, turned
//! into PJRT literals in the positional (name-sorted) ABI order.

use crate::anyhow;
use crate::util::error::{Context, Result};

/// One seq bucket's parameters as ready-to-pass literals.
pub struct ParamSet {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub literals: Vec<xla::Literal>,
}

impl ParamSet {
    /// Load `{model}_params_s{seq}.bin` + `.manifest` from `dir`.
    pub fn load(dir: &str, model: &str, seq: usize) -> Result<ParamSet> {
        let manifest_path = format!("{dir}/{model}_params_s{seq}.manifest");
        let bin_path = format!("{dir}/{model}_params_s{seq}.bin");
        let manifest = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path}"))?;
        let blob = std::fs::read(&bin_path).with_context(|| format!("reading {bin_path}"))?;

        let mut names = Vec::new();
        let mut shapes = Vec::new();
        let mut literals = Vec::new();
        let mut offset = 0usize;
        for line in manifest.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (name, dims) = line
                .split_once(':')
                .ok_or_else(|| anyhow!("bad manifest line '{line}'"))?;
            let shape: Vec<usize> = dims
                .split('x')
                .map(|d| d.parse::<usize>())
                .collect::<Result<Vec<_>, _>>()?;
            let count: usize = shape.iter().product();
            let bytes = count * 4;
            if offset + bytes > blob.len() {
                return Err(anyhow!("param blob too short at '{name}'"));
            }
            let mut values = Vec::with_capacity(count);
            for i in 0..count {
                let b = &blob[offset + i * 4..offset + i * 4 + 4];
                values.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            offset += bytes;
            let dims_i64: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&values).reshape(&dims_i64)?;
            names.push(name.to_string());
            shapes.push(shape);
            literals.push(lit);
        }
        if offset != blob.len() {
            return Err(anyhow!(
                "param blob has {} trailing bytes",
                blob.len() - offset
            ));
        }
        Ok(ParamSet {
            names,
            shapes,
            literals,
        })
    }

    /// Total parameter bytes (the paper's "parameter memory").
    pub fn total_bytes(&self) -> usize {
        self.shapes
            .iter()
            .map(|s| s.iter().product::<usize>() * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_exported_params_if_present() {
        let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
        if !std::path::Path::new(&format!("{dir}/gpt_params_s64.manifest")).exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let ps = ParamSet::load(&dir, "gpt", 64).unwrap();
        assert!(ps.names.len() > 10);
        assert_eq!(ps.names.len(), ps.literals.len());
        // names must be sorted (the positional ABI of positional_forward)
        let mut sorted = ps.names.clone();
        sorted.sort();
        assert_eq!(ps.names, sorted);
        assert!(ps.total_bytes() > 100_000);
    }
}
