//! Requests, responses, and synthetic workload generation.

/// An inference request: prefill of `tokens`, optionally followed by
/// autoregressive generation of `max_new_tokens` tokens against a KV
/// cache (DESIGN.md §13).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    /// Tokens to generate after prefill (0 = prefill-only, the legacy
    /// request shape). Generation routes to a bucket that holds
    /// `seq_len + max_new_tokens` so the KV cache never overflows.
    pub max_new_tokens: usize,
    /// Synthetic arrival offset from workload start (open-loop traces).
    pub arrival_offset_us: u64,
    /// Arrival tick for the continuous-batching engine: the engine's
    /// virtual clock admits a request only once its tick has passed, so
    /// open-loop traces replay deterministically on any machine.
    pub arrival_tick: u64,
    /// Ticks after arrival by which the request must finish (0 = no
    /// deadline). Checked at admission and between decode steps; a miss
    /// surfaces as `Rejected { reason: DeadlineMissed }` — never a hang.
    pub deadline_ticks: u64,
    /// Priority class: within an arrival tick, higher classes admit
    /// first (ties broken by id). 0 is the default best-effort class.
    pub priority: u8,
}

impl Request {
    /// A request with deterministic filler tokens.
    pub fn new(id: usize, seq_len: usize, seed: i32) -> Request {
        let tokens = (0..seq_len)
            .map(|i| ((seed as usize + i * 31) % 512) as i32)
            .collect();
        Request {
            id,
            seq_len,
            tokens,
            max_new_tokens: 0,
            arrival_offset_us: 0,
            arrival_tick: 0,
            deadline_ticks: 0,
            priority: 0,
        }
    }

    /// Builder: set the arrival tick (and a matching µs offset).
    pub fn at_tick(mut self, tick: u64, tick_us: u64) -> Request {
        self.arrival_tick = tick;
        self.arrival_offset_us = tick * tick_us;
        self
    }

    /// Builder: request `n` generated tokens after prefill.
    pub fn generate(mut self, n: usize) -> Request {
        self.max_new_tokens = n;
        self
    }

    /// Builder: require completion within `ticks` of arrival (0 = none).
    pub fn deadline(mut self, ticks: u64) -> Request {
        self.deadline_ticks = ticks;
        self
    }

    /// Builder: set the priority class (higher admits first).
    pub fn with_priority(mut self, p: u8) -> Request {
        self.priority = p;
        self
    }

    /// Total sequence footprint the request's bucket must hold: the
    /// prompt plus every generated position that is fed back. The final
    /// generated token is returned but never re-embedded or cached, so
    /// it needs no position of its own.
    pub fn total_len(&self) -> usize {
        self.seq_len + self.max_new_tokens.saturating_sub(1)
    }
}

/// How a request finished.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RequestOutcome {
    Completed,
    /// No variant fits the memory budget (the "memory wall").
    Rejected,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: usize,
    pub outcome: RequestOutcome,
    /// Artifact tag that served the request (empty when rejected).
    pub variant: String,
    pub latency_us: u64,
}

/// Deterministic synthetic workload: `count` requests with lengths in
/// `[min_len, max_len]`, xorshift-distributed (long-tailed enough to mix
/// buckets). Mirrors the paper's varying-input-length serving scenario.
pub fn synthetic_workload(count: usize, min_len: usize, max_len: usize, seed: u64) -> Vec<Request> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    (0..count)
        .map(|id| {
            let span = (max_len - min_len).max(1) as u64;
            let len = min_len + (rnd() % span) as usize;
            let mut r = Request::new(id, len, (rnd() % 512) as i32);
            r.arrival_offset_us = id as u64 * 500;
            r
        })
        .collect()
}

/// Open-loop workload for the continuous-batching engine: like
/// [`synthetic_workload`], but `per_tick` requests arrive at each tick,
/// so admission pressure (and hence wave packing) is part of the trace.
pub fn open_loop_workload(
    count: usize,
    min_len: usize,
    max_len: usize,
    seed: u64,
    per_tick: usize,
) -> Vec<Request> {
    let per_tick = per_tick.max(1);
    synthetic_workload(count, min_len, max_len, seed)
        .into_iter()
        .map(|r| {
            let tick = (r.id / per_tick) as u64;
            r.at_tick(tick, 500)
        })
        .collect()
}

/// Open-loop *generation* workload: like [`open_loop_workload`], but every
/// request also asks for `min_new..=max_new` generated tokens (xorshift
/// from the same id-stable stream, so traces replay deterministically).
pub fn generate_workload(
    count: usize,
    min_len: usize,
    max_len: usize,
    min_new: usize,
    max_new: usize,
    seed: u64,
    per_tick: usize,
) -> Vec<Request> {
    assert!(min_new >= 1 && max_new >= min_new);
    let mut state = seed.wrapping_mul(0xD1B54A32D192ED03) | 1;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    open_loop_workload(count, min_len, max_len, seed, per_tick)
        .into_iter()
        .map(|r| {
            let span = (max_new - min_new + 1) as u64;
            let n = min_new + (rnd() % span) as usize;
            r.generate(n)
        })
        .collect()
}

/// Open-loop *Poisson* generation workload: like [`generate_workload`],
/// but arrival ticks follow a Poisson process at `rate_per_tick`
/// (exponential inter-arrival gaps, inverse-CDF sampled from the same
/// deterministic xorshift stream). This is the serving-paper workload
/// shape — bursts and lulls instead of a fixed per-tick drip — so queue
/// depth, and hence TTFT/ITL tail latency, is part of the trace.
pub fn poisson_workload(
    count: usize,
    min_len: usize,
    max_len: usize,
    min_new: usize,
    max_new: usize,
    seed: u64,
    rate_per_tick: f64,
) -> Vec<Request> {
    assert!(rate_per_tick > 0.0, "arrival rate must be positive");
    let mut state = seed.wrapping_mul(0xA24BAED4963EE407) | 1;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    let mut t = 0.0f64;
    generate_workload(count, min_len, max_len, min_new, max_new, seed, 1)
        .into_iter()
        .map(|r| {
            // u ∈ (0, 1]: 53 high bits + 1 so ln never sees zero
            let u = ((rnd() >> 11) as f64 + 1.0) / (1u64 << 53) as f64;
            t += -u.ln() / rate_per_tick;
            r.at_tick(t as u64, 500)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic() {
        let a = synthetic_workload(10, 8, 64, 42);
        let b = synthetic_workload(10, 8, 64, 42);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seq_len, y.seq_len);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn open_loop_assigns_monotone_ticks() {
        let reqs = open_loop_workload(9, 8, 32, 5, 3);
        assert_eq!(reqs.len(), 9);
        let ticks: Vec<u64> = reqs.iter().map(|r| r.arrival_tick).collect();
        assert_eq!(ticks, vec![0, 0, 0, 1, 1, 1, 2, 2, 2]);
        for r in &reqs {
            assert_eq!(r.arrival_offset_us, r.arrival_tick * 500);
        }
    }

    #[test]
    fn generate_workload_sets_new_token_counts() {
        let a = generate_workload(12, 8, 32, 2, 6, 9, 3);
        let b = generate_workload(12, 8, 32, 2, 6, 9, 3);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.max_new_tokens, y.max_new_tokens, "not deterministic");
            assert!((2..=6).contains(&x.max_new_tokens));
            assert_eq!(x.total_len(), x.seq_len + x.max_new_tokens - 1);
        }
    }

    #[test]
    fn poisson_workload_is_deterministic_and_monotone() {
        let a = poisson_workload(40, 8, 32, 2, 6, 11, 0.5);
        let b = poisson_workload(40, 8, 32, 2, 6, 11, 0.5);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_tick, y.arrival_tick, "not deterministic");
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let ticks: Vec<u64> = a.iter().map(|r| r.arrival_tick).collect();
        assert!(ticks.windows(2).all(|w| w[0] <= w[1]), "{ticks:?}");
        // mean inter-arrival ≈ 1/rate = 2 ticks: the 40th arrival should
        // land far from 0 but nowhere near a degenerate spread
        let last = *ticks.last().unwrap();
        assert!((20..=320).contains(&last), "last arrival at {last}");
        for r in &a {
            assert_eq!(r.arrival_offset_us, r.arrival_tick * 500);
        }
    }

    #[test]
    fn poisson_rate_scales_arrival_span() {
        let slow = poisson_workload(30, 8, 32, 2, 4, 3, 0.25);
        let fast = poisson_workload(30, 8, 32, 2, 4, 3, 4.0);
        assert!(
            slow.last().unwrap().arrival_tick > fast.last().unwrap().arrival_tick,
            "quadrupled rate should compress the trace"
        );
    }

    #[test]
    fn lengths_within_bounds() {
        for r in synthetic_workload(100, 16, 128, 7) {
            assert!((16..128).contains(&r.seq_len));
            assert_eq!(r.tokens.len(), r.seq_len);
            assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        }
    }
}
