//! Requests, responses, and synthetic workload generation.

/// An inference request (prefill of `tokens`).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    /// Synthetic arrival offset from workload start (open-loop traces).
    pub arrival_offset_us: u64,
    /// Arrival tick for the continuous-batching engine: the engine's
    /// virtual clock admits a request only once its tick has passed, so
    /// open-loop traces replay deterministically on any machine.
    pub arrival_tick: u64,
}

impl Request {
    /// A request with deterministic filler tokens.
    pub fn new(id: usize, seq_len: usize, seed: i32) -> Request {
        let tokens = (0..seq_len)
            .map(|i| ((seed as usize + i * 31) % 512) as i32)
            .collect();
        Request {
            id,
            seq_len,
            tokens,
            arrival_offset_us: 0,
            arrival_tick: 0,
        }
    }

    /// Builder: set the arrival tick (and a matching µs offset).
    pub fn at_tick(mut self, tick: u64, tick_us: u64) -> Request {
        self.arrival_tick = tick;
        self.arrival_offset_us = tick * tick_us;
        self
    }
}

/// How a request finished.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RequestOutcome {
    Completed,
    /// No variant fits the memory budget (the "memory wall").
    Rejected,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: usize,
    pub outcome: RequestOutcome,
    /// Artifact tag that served the request (empty when rejected).
    pub variant: String,
    pub latency_us: u64,
}

/// Deterministic synthetic workload: `count` requests with lengths in
/// `[min_len, max_len]`, xorshift-distributed (long-tailed enough to mix
/// buckets). Mirrors the paper's varying-input-length serving scenario.
pub fn synthetic_workload(count: usize, min_len: usize, max_len: usize, seed: u64) -> Vec<Request> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let mut rnd = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    (0..count)
        .map(|id| {
            let span = (max_len - min_len).max(1) as u64;
            let len = min_len + (rnd() % span) as usize;
            let mut r = Request::new(id, len, (rnd() % 512) as i32);
            r.arrival_offset_us = id as u64 * 500;
            r
        })
        .collect()
}

/// Open-loop workload for the continuous-batching engine: like
/// [`synthetic_workload`], but `per_tick` requests arrive at each tick,
/// so admission pressure (and hence wave packing) is part of the trace.
pub fn open_loop_workload(
    count: usize,
    min_len: usize,
    max_len: usize,
    seed: u64,
    per_tick: usize,
) -> Vec<Request> {
    let per_tick = per_tick.max(1);
    synthetic_workload(count, min_len, max_len, seed)
        .into_iter()
        .map(|r| {
            let tick = (r.id / per_tick) as u64;
            r.at_tick(tick, 500)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic() {
        let a = synthetic_workload(10, 8, 64, 42);
        let b = synthetic_workload(10, 8, 64, 42);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seq_len, y.seq_len);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn open_loop_assigns_monotone_ticks() {
        let reqs = open_loop_workload(9, 8, 32, 5, 3);
        assert_eq!(reqs.len(), 9);
        let ticks: Vec<u64> = reqs.iter().map(|r| r.arrival_tick).collect();
        assert_eq!(ticks, vec![0, 0, 0, 1, 1, 1, 2, 2, 2]);
        for r in &reqs {
            assert_eq!(r.arrival_offset_us, r.arrival_tick * 500);
        }
    }

    #[test]
    fn lengths_within_bounds() {
        for r in synthetic_workload(100, 16, 128, 7) {
            assert!((16..128).contains(&r.seq_len));
            assert_eq!(r.tokens.len(), r.seq_len);
            assert!(r.tokens.iter().all(|&t| (0..512).contains(&t)));
        }
    }
}
