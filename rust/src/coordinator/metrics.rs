//! Serving metrics: latency distribution, throughput, per-variant counts.

use std::collections::HashMap;
use std::time::Duration;

/// Accumulates per-request observations during a serve run.
#[derive(Debug, Default)]
pub struct Recorder {
    latencies_us: Vec<u64>,
    waits_us: Vec<u64>,
    /// Per-phase execution latencies (generation path, DESIGN.md §13):
    /// one prefill sample per admitted prefill, one decode sample per
    /// decode step.
    prefill_us: Vec<u64>,
    decode_us: Vec<u64>,
    /// SLO latencies (DESIGN.md §17): time-to-first-token — queueing wait
    /// plus every prefill slice's execution — one sample per generation
    /// that reached its first token; and inter-token latency — wall time
    /// between consecutive emissions of one stream — one sample per
    /// decode step past the first token.
    ttft_us: Vec<u64>,
    itl_us: Vec<u64>,
    tokens: usize,
    pub per_variant: HashMap<String, usize>,
    pub waves: usize,
    pub rejected: usize,
    /// Requests preempted to a deeper-chunked retry instead of rejected.
    pub preempted: usize,
    /// Compiled-plan cache hits/misses during the run.
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Measured (allocator-tracked) peak activation bytes across the run.
    pub measured_peak_bytes: usize,
    /// Tracked bytes still live when the run finished (0 when every
    /// intermediate, input, and KV cache was released — the eviction
    /// contract the engine tests pin).
    pub measured_final_bytes: usize,
    /// Tokens produced by autoregressive generation.
    pub generated_tokens: usize,
    /// High-water mark of resident KV-cache bytes across the run.
    pub resident_kv_high_water_bytes: usize,
    /// Generations evicted under memory pressure and re-queued for
    /// chunk-planned re-prefill recompute (paged mode, DESIGN.md §14).
    pub evicted: usize,
    /// Prompt-prefix blocks served from the shared pool instead of being
    /// stored twice (paged mode).
    pub shared_prefix_hits: usize,
    /// KV blocks still held when the run finished (paged mode; the drain
    /// contract pins this at 0).
    pub final_blocks_in_use: usize,
    /// High-water mark of concurrently resident generations
    /// (via [`Recorder::observe_concurrent_gens`]).
    max_concurrent_gens: usize,
    /// Requests shed with a structured [`RejectReason`] instead of being
    /// silently dropped (load shedding, DESIGN.md §15).
    pub shed: usize,
    /// Requests rejected because their tick deadline expired before
    /// admission or mid-decode.
    pub deadline_missed: usize,
    /// Retry attempts scheduled after recoverable faults (each adds a
    /// deterministic exponential backoff before re-admission).
    pub retries: usize,
    /// Faults actually fired by the installed [`FaultPlan`] (0 when no
    /// plan is installed).
    pub fault_injections: u64,
    /// Quiescent points checked by the invariant auditor.
    pub waves_audited: usize,
    /// Invariant violations the auditor collected (chaos soak pins 0).
    pub audit_violations: usize,
    /// The auditor's violation messages, verbatim.
    pub audit_log: Vec<String>,
    /// Engine errors observed during the run, bucketed by
    /// [`EngineError::kind`] (includes recovered/retried ones).
    pub errors_by_kind: HashMap<String, usize>,
    /// Graph dispatches spent on decode steps (one per looped per-request
    /// step, one per fused batched wave group) — the batching lever's
    /// direct measure: batched waves hold this constant in wave width
    /// where the looped path grows linearly (DESIGN.md §16).
    pub decode_dispatches: usize,
    /// Waves that executed at least one decode entry.
    pub decode_waves: usize,
    /// Batched decode wave groups assembled (0 when `batch_decode` off).
    pub batched_decode_groups: usize,
    /// Requests shed while still *waiting* (queued, never admitted) —
    /// the complement that keeps the wait percentiles honest: waits are
    /// admitted-only samples (recorded at a request's first admission),
    /// so a run that sheds its stragglers reports this count alongside.
    pub shed_wait: usize,
    /// Chunked-prefill slices executed (0 with `prefill_chunk_tokens` 0).
    pub prefill_slices: usize,
    /// Waves where a prefill slice and a decode step shared the wave —
    /// the interleaving that bounds decode ITL under long prompts.
    pub interleaved_waves: usize,
    /// Generations whose KV blocks were parked in the simulated slow
    /// tier under stall pressure instead of dropped for re-prefill
    /// recompute (spill tier, DESIGN.md §18; 0 with `spill_gbps` 0).
    pub kv_spills: usize,
    /// Parked KV tables restored into the pool (restore-on-touch).
    pub kv_restores: usize,
    /// Bytes moved fast → slow across all KV spills.
    pub kv_spill_bytes: usize,
    /// Bytes moved slow → fast across all KV restores.
    pub kv_restore_bytes: usize,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, variant: &str, latency_us: u64, seq_len: usize) {
        self.latencies_us.push(latency_us);
        self.tokens += seq_len;
        *self.per_variant.entry(variant.to_string()).or_default() += 1;
    }

    /// Queueing delay between a request's arrival and its admission.
    pub fn record_wait(&mut self, wait_us: u64) {
        self.waits_us.push(wait_us);
    }

    /// One prefill execution's wall time.
    pub fn record_prefill(&mut self, us: u64) {
        self.prefill_us.push(us);
    }

    /// One decode step's wall time (including token selection).
    pub fn record_decode(&mut self, us: u64) {
        self.decode_us.push(us);
        self.generated_tokens += 1;
    }

    /// One generation's time-to-first-token (queueing wait + all prefill
    /// slice executions, up to the LM head that selected the token).
    pub fn record_ttft(&mut self, us: u64) {
        self.ttft_us.push(us);
    }

    /// One inter-token gap: wall time since the same stream's previous
    /// emission.
    pub fn record_itl(&mut self, us: u64) {
        self.itl_us.push(us);
    }

    /// Observe the current resident KV-cache footprint (call after each
    /// wave; the report keeps the high-water mark).
    pub fn observe_resident_kv(&mut self, bytes: usize) {
        self.resident_kv_high_water_bytes = self.resident_kv_high_water_bytes.max(bytes);
    }

    /// Observe how many generations are co-resident (call after each
    /// wave's prefills land, before finished ones evict).
    pub fn observe_concurrent_gens(&mut self, n: usize) {
        self.max_concurrent_gens = self.max_concurrent_gens.max(n);
    }

    /// Count one engine error by its stable kind string.
    pub fn record_error(&mut self, kind: &str) {
        *self.errors_by_kind.entry(kind.to_string()).or_default() += 1;
    }

    /// Close the run and compute the report.
    pub fn finish(mut self, wall: Duration) -> MetricsReport {
        self.latencies_us.sort_unstable();
        self.waits_us.sort_unstable();
        self.prefill_us.sort_unstable();
        self.decode_us.sort_unstable();
        self.ttft_us.sort_unstable();
        self.itl_us.sort_unstable();
        let completed = self.latencies_us.len();
        let pct = |v: &[u64], p: f64| -> u64 {
            if v.is_empty() {
                return 0;
            }
            let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
            v[idx]
        };
        let wall_s = wall.as_secs_f64().max(1e-9);
        MetricsReport {
            completed,
            rejected: self.rejected,
            preempted: self.preempted,
            waves: self.waves,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            measured_peak_bytes: self.measured_peak_bytes,
            measured_final_bytes: self.measured_final_bytes,
            wall_seconds: wall_s,
            throughput_rps: completed as f64 / wall_s,
            throughput_tokens_s: self.tokens as f64 / wall_s,
            p50_us: pct(&self.latencies_us, 0.50),
            p95_us: pct(&self.latencies_us, 0.95),
            p99_us: pct(&self.latencies_us, 0.99),
            wait_p50_us: pct(&self.waits_us, 0.50),
            wait_p99_us: pct(&self.waits_us, 0.99),
            prefill_p50_us: pct(&self.prefill_us, 0.50),
            prefill_p99_us: pct(&self.prefill_us, 0.99),
            decode_p50_us: pct(&self.decode_us, 0.50),
            decode_p99_us: pct(&self.decode_us, 0.99),
            decode_steps: self.decode_us.len(),
            generated_tokens: self.generated_tokens,
            resident_kv_high_water_bytes: self.resident_kv_high_water_bytes,
            evicted: self.evicted,
            shared_prefix_hits: self.shared_prefix_hits,
            final_blocks_in_use: self.final_blocks_in_use,
            max_concurrent_generations: self.max_concurrent_gens,
            shed: self.shed,
            deadline_missed: self.deadline_missed,
            retries: self.retries,
            fault_injections: self.fault_injections,
            waves_audited: self.waves_audited,
            audit_violations: self.audit_violations,
            audit_log: self.audit_log,
            errors_by_kind: self.errors_by_kind,
            decode_dispatches: self.decode_dispatches,
            decode_waves: self.decode_waves,
            batched_decode_groups: self.batched_decode_groups,
            shed_wait: self.shed_wait,
            prefill_slices: self.prefill_slices,
            interleaved_waves: self.interleaved_waves,
            kv_spills: self.kv_spills,
            kv_restores: self.kv_restores,
            kv_spill_bytes: self.kv_spill_bytes,
            kv_restore_bytes: self.kv_restore_bytes,
            ttft_p50_us: pct(&self.ttft_us, 0.50),
            ttft_p99_us: pct(&self.ttft_us, 0.99),
            itl_p50_us: pct(&self.itl_us, 0.50),
            itl_p99_us: pct(&self.itl_us, 0.99),
            itl_samples: self.itl_us.len(),
            mean_us: if completed == 0 {
                0
            } else {
                self.latencies_us.iter().sum::<u64>() / completed as u64
            },
            per_variant: self.per_variant,
        }
    }
}

/// Summary of a serve run.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: usize,
    pub rejected: usize,
    /// Requests preempted to a deeper-chunked retry (still completed or
    /// rejected eventually; this counts the deepening events).
    pub preempted: usize,
    pub waves: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Measured peak activation bytes across the run (0 when the backend
    /// does not track allocations, e.g. the PJRT tier).
    pub measured_peak_bytes: usize,
    /// Tracked bytes still live at run end (eviction soundness: 0 when
    /// all caches were released).
    pub measured_final_bytes: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub throughput_tokens_s: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Queueing-delay percentiles (admission tick − arrival tick).
    pub wait_p50_us: u64,
    pub wait_p99_us: u64,
    /// Prefill vs decode execution-latency breakdown (generation path;
    /// zeros when the run generated nothing).
    pub prefill_p50_us: u64,
    pub prefill_p99_us: u64,
    pub decode_p50_us: u64,
    pub decode_p99_us: u64,
    /// Decode steps executed across the run.
    pub decode_steps: usize,
    /// Tokens produced by autoregressive generation.
    pub generated_tokens: usize,
    /// High-water mark of resident KV-cache bytes (0 when no caches were
    /// bound; always ≤ measured peak since caches allocate on the run's
    /// tracker). Under either cache backend this is *true residency* —
    /// bytes held, which for the paged pool is blocks in use and for the
    /// contiguous cache coincides with reserved capacity.
    pub resident_kv_high_water_bytes: usize,
    /// Generations evicted to recompute under memory pressure (paged).
    pub evicted: usize,
    /// Prompt-prefix blocks deduplicated by sharing (paged).
    pub shared_prefix_hits: usize,
    /// KV blocks held at run end — the paged drain contract pins 0.
    pub final_blocks_in_use: usize,
    /// High-water mark of concurrently resident generations.
    pub max_concurrent_generations: usize,
    /// Requests shed with a structured reject reason (DESIGN.md §15).
    pub shed: usize,
    /// Requests whose tick deadline expired before they finished.
    pub deadline_missed: usize,
    /// Retry attempts scheduled after recoverable faults.
    pub retries: usize,
    /// Faults fired by the installed fault plan (0 without one).
    pub fault_injections: u64,
    /// Quiescent points the invariant auditor checked (0 when auditing
    /// was off).
    pub waves_audited: usize,
    /// Invariant violations collected — the chaos soak pins this at 0.
    pub audit_violations: usize,
    /// The auditor's violation messages, verbatim.
    pub audit_log: Vec<String>,
    /// Engine errors bucketed by stable kind string.
    pub errors_by_kind: HashMap<String, usize>,
    /// Graph dispatches spent on decode steps (looped: one per request
    /// per step; batched: one per wave group per step).
    pub decode_dispatches: usize,
    /// Waves that executed at least one decode entry.
    pub decode_waves: usize,
    /// Batched decode wave groups assembled (0 with `batch_decode` off).
    pub batched_decode_groups: usize,
    /// Requests shed while queued (never admitted) — the complement of
    /// the admitted-only wait percentiles.
    pub shed_wait: usize,
    /// Chunked-prefill slices executed across the run.
    pub prefill_slices: usize,
    /// Waves where a prefill slice and a decode step shared the wave.
    pub interleaved_waves: usize,
    /// Generations spilled to the simulated slow tier under stall
    /// pressure (spill tier, DESIGN.md §18; 0 with `spill_gbps` 0).
    pub kv_spills: usize,
    /// Parked KV tables restored into the pool.
    pub kv_restores: usize,
    /// Bytes moved fast → slow across all KV spills.
    pub kv_spill_bytes: usize,
    /// Bytes moved slow → fast across all KV restores.
    pub kv_restore_bytes: usize,
    /// Time-to-first-token percentiles (queueing wait + prefill
    /// execution; zeros when nothing generated).
    pub ttft_p50_us: u64,
    pub ttft_p99_us: u64,
    /// Inter-token-latency percentiles — the decode-SLO number chunked
    /// prefill exists to bound (zeros below two emissions per stream).
    pub itl_p50_us: u64,
    pub itl_p99_us: u64,
    /// Inter-token gaps sampled across the run.
    pub itl_samples: usize,
    pub mean_us: u64,
    pub per_variant: HashMap<String, usize>,
}

impl MetricsReport {
    /// Human-readable multi-line summary for CLI/examples.
    pub fn render(&self) -> String {
        let mut variants: Vec<_> = self.per_variant.iter().collect();
        variants.sort();
        let vstr = variants
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        let mut s = format!(
            "completed={} rejected={} preempted={} waves={} wall={:.2}s\n\
             throughput={:.2} req/s ({:.0} tok/s)\n\
             latency mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n\
             wait p50={:.2}ms p99={:.2}ms | plan cache {}h/{}m | peak {:.1} MiB\n\
             variants: {vstr}",
            self.completed,
            self.rejected,
            self.preempted,
            self.waves,
            self.wall_seconds,
            self.throughput_rps,
            self.throughput_tokens_s,
            self.mean_us as f64 / 1e3,
            self.p50_us as f64 / 1e3,
            self.p95_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
            self.wait_p50_us as f64 / 1e3,
            self.wait_p99_us as f64 / 1e3,
            self.cache_hits,
            self.cache_misses,
            self.measured_peak_bytes as f64 / (1 << 20) as f64,
        );
        if self.generated_tokens > 0 {
            s.push_str(&format!(
                "\ngenerated {} tokens in {} decode steps | prefill p50={:.2}ms p99={:.2}ms | \
                 decode p50={:.2}ms p99={:.2}ms | resident kv high-water {:.1} MiB | \
                 {} concurrent | evicted={} shared-prefix-hits={}",
                self.generated_tokens,
                self.decode_steps,
                self.prefill_p50_us as f64 / 1e3,
                self.prefill_p99_us as f64 / 1e3,
                self.decode_p50_us as f64 / 1e3,
                self.decode_p99_us as f64 / 1e3,
                self.resident_kv_high_water_bytes as f64 / (1 << 20) as f64,
                self.max_concurrent_generations,
                self.evicted,
                self.shared_prefix_hits,
            ));
            if self.decode_waves > 0 {
                s.push_str(&format!(
                    "\ndecode dispatches: {} over {} decode waves ({:.2}/wave, {} batched groups)",
                    self.decode_dispatches,
                    self.decode_waves,
                    self.decode_dispatches as f64 / self.decode_waves as f64,
                    self.batched_decode_groups,
                ));
            }
            if self.ttft_p99_us > 0 || self.itl_samples > 0 {
                s.push_str(&format!(
                    "\nslo: ttft p50={:.2}ms p99={:.2}ms | itl p50={:.2}ms p99={:.2}ms \
                     ({} gaps)",
                    self.ttft_p50_us as f64 / 1e3,
                    self.ttft_p99_us as f64 / 1e3,
                    self.itl_p50_us as f64 / 1e3,
                    self.itl_p99_us as f64 / 1e3,
                    self.itl_samples,
                ));
            }
            if self.prefill_slices > 0 {
                s.push_str(&format!(
                    "\nchunked prefill: {} slices, {} interleaved waves",
                    self.prefill_slices, self.interleaved_waves,
                ));
            }
            if self.kv_spills + self.kv_restores > 0 {
                s.push_str(&format!(
                    "\nspill tier: {} kv spills ({:.1} MiB out), {} restores ({:.1} MiB in)",
                    self.kv_spills,
                    self.kv_spill_bytes as f64 / (1 << 20) as f64,
                    self.kv_restores,
                    self.kv_restore_bytes as f64 / (1 << 20) as f64,
                ));
            }
        }
        let total_errors: usize = self.errors_by_kind.values().sum();
        if self.shed
            + self.shed_wait
            + self.deadline_missed
            + self.retries
            + self.waves_audited
            + total_errors
            > 0
            || self.fault_injections > 0
        {
            let mut kinds: Vec<_> = self.errors_by_kind.iter().collect();
            kinds.sort();
            let kstr = kinds
                .iter()
                .map(|(k, v)| format!("{k}:{v}"))
                .collect::<Vec<_>>()
                .join(" ");
            s.push_str(&format!(
                "\nrobustness: shed={} shed-wait={} deadline-missed={} retries={} \
                 faults-injected={} | audited {} waves, {} violations | errors: {}",
                self.shed,
                self.shed_wait,
                self.deadline_missed,
                self.retries,
                self.fault_injections,
                self.waves_audited,
                self.audit_violations,
                if kstr.is_empty() { "none".to_string() } else { kstr },
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_computed() {
        let mut r = Recorder::new();
        for i in 1..=100u64 {
            r.record("v", i * 1000, 64);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.completed, 100);
        assert_eq!(rep.p50_us, 51_000); // nearest-rank of 1..=100
        assert_eq!(rep.p95_us, 94_000_u64.max(rep.p95_us.min(96_000)));
        assert!(rep.p99_us >= rep.p95_us);
        assert!(rep.throughput_rps > 99.0);
        assert_eq!(rep.per_variant["v"], 100);
    }

    #[test]
    fn empty_run_safe() {
        let rep = Recorder::new().finish(Duration::from_millis(10));
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.p99_us, 0);
        assert_eq!(rep.wait_p99_us, 0);
    }

    #[test]
    fn wait_percentiles_computed() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        for w in [100u64, 200, 300, 400] {
            r.record_wait(w);
        }
        r.preempted = 2;
        r.cache_hits = 3;
        r.cache_misses = 1;
        r.measured_peak_bytes = 5 << 20;
        let rep = r.finish(Duration::from_secs(1));
        assert!(rep.wait_p50_us >= 100 && rep.wait_p50_us <= 300);
        assert_eq!(rep.wait_p99_us, 400);
        assert_eq!(rep.preempted, 2);
        assert_eq!(rep.cache_hits, 3);
        assert_eq!(rep.cache_misses, 1);
        let s = rep.render();
        assert!(s.contains("preempted=2"), "{s}");
        assert!(s.contains("3h/1m"), "{s}");
    }

    #[test]
    fn decode_breakdown_percentiles() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_prefill(4000);
        r.record_prefill(6000);
        for d in [100u64, 200, 300, 400] {
            r.record_decode(d);
        }
        r.observe_resident_kv(3 << 20);
        r.observe_resident_kv(1 << 20); // high-water keeps the max
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.generated_tokens, 4);
        assert_eq!(rep.decode_steps, 4);
        assert!(rep.prefill_p50_us >= 4000 && rep.prefill_p99_us <= 6000);
        assert!(rep.decode_p50_us >= 100 && rep.decode_p50_us <= 300);
        assert_eq!(rep.decode_p99_us, 400);
        assert!(rep.decode_p99_us >= rep.decode_p50_us);
        assert_eq!(rep.resident_kv_high_water_bytes, 3 << 20);
        let s = rep.render();
        assert!(s.contains("generated 4 tokens"), "{s}");
        assert!(s.contains("resident kv high-water"), "{s}");
    }

    #[test]
    fn prefill_only_run_renders_without_decode_line() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.generated_tokens, 0);
        assert_eq!(rep.decode_p99_us, 0);
        assert!(!rep.render().contains("generated"));
    }

    #[test]
    fn robustness_line_renders_only_when_active() {
        // A plain run must not mention the chaos machinery at all.
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        let quiet = r.finish(Duration::from_secs(1));
        assert_eq!(quiet.shed, 0);
        assert!(quiet.errors_by_kind.is_empty());
        assert!(!quiet.render().contains("robustness"), "{}", quiet.render());

        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.shed = 2;
        r.deadline_missed = 1;
        r.retries = 3;
        r.fault_injections = 5;
        r.waves_audited = 4;
        r.record_error("kernel_poisoned");
        r.record_error("kernel_poisoned");
        r.record_error("block_alloc");
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.errors_by_kind["kernel_poisoned"], 2);
        assert_eq!(rep.errors_by_kind["block_alloc"], 1);
        let s = rep.render();
        assert!(s.contains("shed=2"), "{s}");
        assert!(s.contains("deadline-missed=1"), "{s}");
        assert!(s.contains("retries=3"), "{s}");
        assert!(s.contains("faults-injected=5"), "{s}");
        assert!(s.contains("kernel_poisoned:2"), "{s}");
    }

    #[test]
    fn slo_percentiles_computed() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100); // makes the generation block render
        for t in [1000u64, 2000, 3000, 4000] {
            r.record_ttft(t);
        }
        for g in [10u64, 20, 30, 40, 400] {
            r.record_itl(g);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert!(rep.ttft_p50_us >= 1000 && rep.ttft_p50_us <= 3000);
        assert_eq!(rep.ttft_p99_us, 4000);
        assert!(rep.itl_p50_us >= 10 && rep.itl_p50_us <= 40);
        assert_eq!(rep.itl_p99_us, 400);
        assert_eq!(rep.itl_samples, 5);
        let s = rep.render();
        assert!(s.contains("ttft"), "{s}");
        assert!(s.contains("itl"), "{s}");
    }

    #[test]
    fn slo_line_absent_without_samples() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.ttft_p99_us, 0);
        assert_eq!(rep.itl_samples, 0);
        assert!(!rep.render().contains("slo:"), "{}", rep.render());
        assert!(!rep.render().contains("chunked prefill"), "{}", rep.render());
    }

    #[test]
    fn shed_wait_and_slice_counters_render() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        r.shed_wait = 3;
        r.prefill_slices = 7;
        r.interleaved_waves = 2;
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.shed_wait, 3);
        assert_eq!(rep.prefill_slices, 7);
        assert_eq!(rep.interleaved_waves, 2);
        let s = rep.render();
        assert!(s.contains("shed-wait=3"), "{s}");
        assert!(s.contains("7 slices"), "{s}");
        assert!(s.contains("2 interleaved waves"), "{s}");
    }

    #[test]
    fn spill_counters_render() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        r.kv_spills = 2;
        r.kv_restores = 1;
        r.kv_spill_bytes = 4 << 20;
        r.kv_restore_bytes = 2 << 20;
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.kv_spills, 2);
        assert_eq!(rep.kv_restores, 1);
        let s = rep.render();
        assert!(s.contains("2 kv spills"), "{s}");
        assert!(s.contains("1 restores"), "{s}");
        // and a run that never spilled must not mention the tier
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        assert!(!r.finish(Duration::from_secs(1)).render().contains("spill tier"));
    }

    #[test]
    fn zero_denominator_ratios_stay_finite() {
        // Zero-length run: every ratio/percentile in the report divides
        // by a guarded denominator — nothing may render NaN or inf
        // (these strings would otherwise leak into BENCH_*.json).
        let rep = Recorder::new().finish(Duration::from_millis(0));
        assert!(rep.wall_seconds > 0.0, "wall clamped away from zero");
        assert!(rep.throughput_rps.is_finite());
        assert!(rep.throughput_tokens_s.is_finite());
        assert_eq!(rep.mean_us, 0);
        let s = rep.render();
        assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
    }

    #[test]
    fn render_contains_key_fields() {
        let mut r = Recorder::new();
        r.record("gpt_dense_s64", 1500, 64);
        let rep = r.finish(Duration::from_secs(1));
        let s = rep.render();
        assert!(s.contains("completed=1"));
        assert!(s.contains("gpt_dense_s64:1"));
    }
}
