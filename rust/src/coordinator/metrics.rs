//! Serving metrics: latency distribution, throughput, per-variant counts.

use std::collections::HashMap;
use std::time::Duration;

/// Accumulates per-request observations during a serve run.
#[derive(Debug, Default)]
pub struct Recorder {
    latencies_us: Vec<u64>,
    tokens: usize,
    pub per_variant: HashMap<String, usize>,
    pub waves: usize,
    pub rejected: usize,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, variant: &str, latency_us: u64, seq_len: usize) {
        self.latencies_us.push(latency_us);
        self.tokens += seq_len;
        *self.per_variant.entry(variant.to_string()).or_default() += 1;
    }

    /// Close the run and compute the report.
    pub fn finish(mut self, wall: Duration) -> MetricsReport {
        self.latencies_us.sort_unstable();
        let completed = self.latencies_us.len();
        let pct = |p: f64| -> u64 {
            if self.latencies_us.is_empty() {
                return 0;
            }
            let idx = ((completed as f64 - 1.0) * p).round() as usize;
            self.latencies_us[idx]
        };
        let wall_s = wall.as_secs_f64().max(1e-9);
        MetricsReport {
            completed,
            rejected: self.rejected,
            waves: self.waves,
            wall_seconds: wall_s,
            throughput_rps: completed as f64 / wall_s,
            throughput_tokens_s: self.tokens as f64 / wall_s,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_us: if completed == 0 {
                0
            } else {
                self.latencies_us.iter().sum::<u64>() / completed as u64
            },
            per_variant: self.per_variant,
        }
    }
}

/// Summary of a serve run.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: usize,
    pub rejected: usize,
    pub waves: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub throughput_tokens_s: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: u64,
    pub per_variant: HashMap<String, usize>,
}

impl MetricsReport {
    /// Human-readable multi-line summary for CLI/examples.
    pub fn render(&self) -> String {
        let mut variants: Vec<_> = self.per_variant.iter().collect();
        variants.sort();
        let vstr = variants
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "completed={} rejected={} waves={} wall={:.2}s\n\
             throughput={:.2} req/s ({:.0} tok/s)\n\
             latency mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n\
             variants: {vstr}",
            self.completed,
            self.rejected,
            self.waves,
            self.wall_seconds,
            self.throughput_rps,
            self.throughput_tokens_s,
            self.mean_us as f64 / 1e3,
            self.p50_us as f64 / 1e3,
            self.p95_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_computed() {
        let mut r = Recorder::new();
        for i in 1..=100u64 {
            r.record("v", i * 1000, 64);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.completed, 100);
        assert_eq!(rep.p50_us, 51_000); // nearest-rank of 1..=100
        assert_eq!(rep.p95_us, 94_000_u64.max(rep.p95_us.min(96_000)));
        assert!(rep.p99_us >= rep.p95_us);
        assert!(rep.throughput_rps > 99.0);
        assert_eq!(rep.per_variant["v"], 100);
    }

    #[test]
    fn empty_run_safe() {
        let rep = Recorder::new().finish(Duration::from_millis(10));
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.p99_us, 0);
    }

    #[test]
    fn render_contains_key_fields() {
        let mut r = Recorder::new();
        r.record("gpt_dense_s64", 1500, 64);
        let rep = r.finish(Duration::from_secs(1));
        let s = rep.render();
        assert!(s.contains("completed=1"));
        assert!(s.contains("gpt_dense_s64:1"));
    }
}
