//! Serving metrics: latency distribution, throughput, per-variant counts.
//!
//! Latency distributions are held in [`LatencySketch`] — a mergeable
//! log-bucket histogram (HDR-histogram style) rather than an unbounded
//! sample vector, so a recorder's footprint is O(1) in run length and
//! two runs' sketches can be merged exactly (DESIGN.md §19).

use std::collections::HashMap;
use std::time::Duration;

/// Bucket count for [`LatencySketch`]: values `< 16` index exactly
/// (buckets `0..16`); above, each power-of-two decade splits into 16
/// sub-buckets (`16 * (64 - 4)` of them covers all of `u64`).
const SKETCH_BUCKETS: usize = 16 + 16 * 60;

/// Mergeable log-bucket latency histogram.
///
/// * values `< 16` are recorded exactly;
/// * larger values land in one of 16 sub-buckets per power-of-two
///   decade, bounding relative quantile error at `1/16` (6.25 %);
/// * `count`, `sum`, `min`, and `max` are exact, so `mean()` is exact
///   and the top quantile (nearest rank in the last occupied bucket)
///   returns the exact maximum — preserving `p99 == max(samples)` for
///   small sample sets;
/// * [`LatencySketch::merge`] adds another sketch in O(buckets), the
///   associative/commutative property batch reporters need.
#[derive(Clone, Debug)]
pub struct LatencySketch {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LatencySketch {
    fn default() -> Self {
        LatencySketch::new()
    }
}

impl LatencySketch {
    pub fn new() -> LatencySketch {
        LatencySketch {
            buckets: vec![0; SKETCH_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn bucket_index(v: u64) -> usize {
        if v < 16 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize; // >= 4
        let shift = msb - 4;
        let sub = ((v >> shift) - 16) as usize; // 0..16
        16 + shift * 16 + sub
    }

    /// Smallest value that lands in bucket `idx` (quantile decode).
    fn bucket_lower(idx: usize) -> u64 {
        if idx < 16 {
            return idx as u64;
        }
        let b = idx - 16;
        (16 + (b % 16) as u64) << (b / 16)
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another sketch in: bucket-wise sum plus exact count/sum
    /// and min/max — `a.merge(&b)` holds every sample either saw.
    pub fn merge(&mut self, other: &LatencySketch) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean, truncated (0 when empty).
    pub fn mean(&self) -> u64 {
        if self.count == 0 { 0 } else { self.sum / self.count }
    }

    /// Nearest-rank quantile, `q` in `[0, 1]`: the lower bound of the
    /// bucket holding the rank (≤ the true sample, within 1/16), except
    /// that a rank landing in the *last* occupied bucket answers with
    /// the exact maximum. Empty sketches answer 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 - 1.0) * q).round() as u64;
        let mut seen = 0u64;
        for (idx, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            seen += n;
            if rank < seen {
                // rank in the top occupied bucket → exact max
                if self.buckets[idx + 1..].iter().all(|&m| m == 0) {
                    return self.max;
                }
                return Self::bucket_lower(idx).max(self.min);
            }
        }
        self.max
    }
}

/// Accumulates per-request observations during a serve run.
#[derive(Debug, Default)]
pub struct Recorder {
    latencies_us: LatencySketch,
    waits_us: LatencySketch,
    /// Per-phase execution latencies (generation path, DESIGN.md §13):
    /// one prefill sample per admitted prefill, one decode sample per
    /// decode step.
    prefill_us: LatencySketch,
    decode_us: LatencySketch,
    /// SLO latencies (DESIGN.md §17): time-to-first-token — queueing wait
    /// plus every prefill slice's execution — one sample per generation
    /// that reached its first token; and inter-token latency — wall time
    /// between consecutive emissions of one stream — one sample per
    /// decode step past the first token.
    ttft_us: LatencySketch,
    itl_us: LatencySketch,
    tokens: usize,
    pub per_variant: HashMap<String, usize>,
    pub waves: usize,
    pub rejected: usize,
    /// Requests preempted to a deeper-chunked retry instead of rejected.
    pub preempted: usize,
    /// Compiled-plan cache hits/misses during the run.
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Measured (allocator-tracked) peak activation bytes across the run.
    pub measured_peak_bytes: usize,
    /// Tracked bytes still live when the run finished (0 when every
    /// intermediate, input, and KV cache was released — the eviction
    /// contract the engine tests pin).
    pub measured_final_bytes: usize,
    /// Tokens produced by autoregressive generation.
    pub generated_tokens: usize,
    /// High-water mark of resident KV-cache bytes across the run.
    pub resident_kv_high_water_bytes: usize,
    /// Generations evicted under memory pressure and re-queued for
    /// chunk-planned re-prefill recompute (paged mode, DESIGN.md §14).
    pub evicted: usize,
    /// Prompt-prefix blocks served from the shared pool instead of being
    /// stored twice (paged mode).
    pub shared_prefix_hits: usize,
    /// KV blocks still held when the run finished (paged mode; the drain
    /// contract pins this at 0).
    pub final_blocks_in_use: usize,
    /// High-water mark of concurrently resident generations
    /// (via [`Recorder::observe_concurrent_gens`]).
    max_concurrent_gens: usize,
    /// Requests shed with a structured [`RejectReason`] instead of being
    /// silently dropped (load shedding, DESIGN.md §15).
    pub shed: usize,
    /// Requests rejected because their tick deadline expired before
    /// admission or mid-decode.
    pub deadline_missed: usize,
    /// Retry attempts scheduled after recoverable faults (each adds a
    /// deterministic exponential backoff before re-admission).
    pub retries: usize,
    /// Faults actually fired by the installed [`FaultPlan`] (0 when no
    /// plan is installed).
    pub fault_injections: u64,
    /// Quiescent points checked by the invariant auditor.
    pub waves_audited: usize,
    /// Invariant violations the auditor collected (chaos soak pins 0).
    pub audit_violations: usize,
    /// The auditor's violation messages, verbatim.
    pub audit_log: Vec<String>,
    /// Engine errors observed during the run, bucketed by
    /// [`EngineError::kind`] (includes recovered/retried ones).
    pub errors_by_kind: HashMap<String, usize>,
    /// Graph dispatches spent on decode steps (one per looped per-request
    /// step, one per fused batched wave group) — the batching lever's
    /// direct measure: batched waves hold this constant in wave width
    /// where the looped path grows linearly (DESIGN.md §16).
    pub decode_dispatches: usize,
    /// Waves that executed at least one decode entry.
    pub decode_waves: usize,
    /// Batched decode wave groups assembled (0 when `batch_decode` off).
    pub batched_decode_groups: usize,
    /// Requests shed while still *waiting* (queued, never admitted) —
    /// the complement that keeps the wait percentiles honest: waits are
    /// admitted-only samples (recorded at a request's first admission),
    /// so a run that sheds its stragglers reports this count alongside.
    pub shed_wait: usize,
    /// Chunked-prefill slices executed (0 with `prefill_chunk_tokens` 0).
    pub prefill_slices: usize,
    /// Waves where a prefill slice and a decode step shared the wave —
    /// the interleaving that bounds decode ITL under long prompts.
    pub interleaved_waves: usize,
    /// Generations whose KV blocks were parked in the simulated slow
    /// tier under stall pressure instead of dropped for re-prefill
    /// recompute (spill tier, DESIGN.md §18; 0 with `spill_gbps` 0).
    pub kv_spills: usize,
    /// Parked KV tables restored into the pool (restore-on-touch).
    pub kv_restores: usize,
    /// Bytes moved fast → slow across all KV spills.
    pub kv_spill_bytes: usize,
    /// Bytes moved slow → fast across all KV restores.
    pub kv_restore_bytes: usize,
    /// Activation-spill traffic summed over executed wave entries
    /// (memory-planner spill tiers, via [`Recorder::absorb_exec`]):
    /// bytes offloaded to the slow tier at spill points.
    pub spill_out_bytes: usize,
    /// Bytes copied back from the slow tier at restore points.
    pub spill_in_bytes: usize,
    /// Spill-script events executed (offload spills + all restores).
    pub spill_events: usize,
    /// Restores served by re-executing the producing node instead of a
    /// slow-tier copy.
    pub spill_recomputes: usize,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn record(&mut self, variant: &str, latency_us: u64, seq_len: usize) {
        self.latencies_us.record(latency_us);
        self.tokens += seq_len;
        *self.per_variant.entry(variant.to_string()).or_default() += 1;
    }

    /// Queueing delay between a request's arrival and its admission.
    pub fn record_wait(&mut self, wait_us: u64) {
        self.waits_us.record(wait_us);
    }

    /// One prefill execution's wall time.
    pub fn record_prefill(&mut self, us: u64) {
        self.prefill_us.record(us);
    }

    /// One decode step's wall time (including token selection).
    pub fn record_decode(&mut self, us: u64) {
        self.decode_us.record(us);
        self.generated_tokens += 1;
    }

    /// One generation's time-to-first-token (queueing wait + all prefill
    /// slice executions, up to the LM head that selected the token).
    pub fn record_ttft(&mut self, us: u64) {
        self.ttft_us.record(us);
    }

    /// One inter-token gap: wall time since the same stream's previous
    /// emission.
    pub fn record_itl(&mut self, us: u64) {
        self.itl_us.record(us);
    }

    /// Fold one executed wave entry's [`crate::exec::ExecStats`] into the
    /// run totals. Only the activation-spill counters are absorbed: they
    /// are pure functions of the memory plan and therefore deterministic
    /// across thread widths, unlike the arena-reuse and peak counters
    /// (which stay per-entry diagnostics).
    pub fn absorb_exec(&mut self, s: &crate::exec::ExecStats) {
        self.spill_out_bytes += s.spill_out_bytes;
        self.spill_in_bytes += s.spill_in_bytes;
        self.spill_events += s.spill_events;
        self.spill_recomputes += s.spill_recomputes;
    }

    /// Observe the current resident KV-cache footprint (call after each
    /// wave; the report keeps the high-water mark).
    pub fn observe_resident_kv(&mut self, bytes: usize) {
        self.resident_kv_high_water_bytes = self.resident_kv_high_water_bytes.max(bytes);
    }

    /// Observe how many generations are co-resident (call after each
    /// wave's prefills land, before finished ones evict).
    pub fn observe_concurrent_gens(&mut self, n: usize) {
        self.max_concurrent_gens = self.max_concurrent_gens.max(n);
    }

    /// Count one engine error by its stable kind string.
    pub fn record_error(&mut self, kind: &str) {
        *self.errors_by_kind.entry(kind.to_string()).or_default() += 1;
    }

    /// Close the run and compute the report.
    pub fn finish(self, wall: Duration) -> MetricsReport {
        let completed = self.latencies_us.count() as usize;
        let wall_s = wall.as_secs_f64().max(1e-9);
        MetricsReport {
            completed,
            rejected: self.rejected,
            preempted: self.preempted,
            waves: self.waves,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            measured_peak_bytes: self.measured_peak_bytes,
            measured_final_bytes: self.measured_final_bytes,
            wall_seconds: wall_s,
            throughput_rps: completed as f64 / wall_s,
            throughput_tokens_s: self.tokens as f64 / wall_s,
            p50_us: self.latencies_us.quantile(0.50),
            p95_us: self.latencies_us.quantile(0.95),
            p99_us: self.latencies_us.quantile(0.99),
            wait_p50_us: self.waits_us.quantile(0.50),
            wait_p99_us: self.waits_us.quantile(0.99),
            prefill_p50_us: self.prefill_us.quantile(0.50),
            prefill_p99_us: self.prefill_us.quantile(0.99),
            decode_p50_us: self.decode_us.quantile(0.50),
            decode_p99_us: self.decode_us.quantile(0.99),
            decode_steps: self.decode_us.count() as usize,
            generated_tokens: self.generated_tokens,
            resident_kv_high_water_bytes: self.resident_kv_high_water_bytes,
            evicted: self.evicted,
            shared_prefix_hits: self.shared_prefix_hits,
            final_blocks_in_use: self.final_blocks_in_use,
            max_concurrent_generations: self.max_concurrent_gens,
            shed: self.shed,
            deadline_missed: self.deadline_missed,
            retries: self.retries,
            fault_injections: self.fault_injections,
            waves_audited: self.waves_audited,
            audit_violations: self.audit_violations,
            audit_log: self.audit_log,
            errors_by_kind: self.errors_by_kind,
            decode_dispatches: self.decode_dispatches,
            decode_waves: self.decode_waves,
            batched_decode_groups: self.batched_decode_groups,
            shed_wait: self.shed_wait,
            prefill_slices: self.prefill_slices,
            interleaved_waves: self.interleaved_waves,
            kv_spills: self.kv_spills,
            kv_restores: self.kv_restores,
            kv_spill_bytes: self.kv_spill_bytes,
            kv_restore_bytes: self.kv_restore_bytes,
            spill_out_bytes: self.spill_out_bytes,
            spill_in_bytes: self.spill_in_bytes,
            spill_events: self.spill_events,
            spill_recomputes: self.spill_recomputes,
            ttft_p50_us: self.ttft_us.quantile(0.50),
            ttft_p99_us: self.ttft_us.quantile(0.99),
            itl_p50_us: self.itl_us.quantile(0.50),
            itl_p99_us: self.itl_us.quantile(0.99),
            itl_samples: self.itl_us.count() as usize,
            mean_us: self.latencies_us.mean(),
            per_variant: self.per_variant,
            latency_sketch: self.latencies_us,
            wait_sketch: self.waits_us,
            prefill_sketch: self.prefill_us,
            decode_sketch: self.decode_us,
            ttft_sketch: self.ttft_us,
            itl_sketch: self.itl_us,
        }
    }
}

/// Summary of a serve run.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    pub completed: usize,
    pub rejected: usize,
    /// Requests preempted to a deeper-chunked retry (still completed or
    /// rejected eventually; this counts the deepening events).
    pub preempted: usize,
    pub waves: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Measured peak activation bytes across the run (0 when the backend
    /// does not track allocations, e.g. the PJRT tier).
    pub measured_peak_bytes: usize,
    /// Tracked bytes still live at run end (eviction soundness: 0 when
    /// all caches were released).
    pub measured_final_bytes: usize,
    pub wall_seconds: f64,
    pub throughput_rps: f64,
    pub throughput_tokens_s: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Queueing-delay percentiles (admission tick − arrival tick).
    pub wait_p50_us: u64,
    pub wait_p99_us: u64,
    /// Prefill vs decode execution-latency breakdown (generation path;
    /// zeros when the run generated nothing).
    pub prefill_p50_us: u64,
    pub prefill_p99_us: u64,
    pub decode_p50_us: u64,
    pub decode_p99_us: u64,
    /// Decode steps executed across the run.
    pub decode_steps: usize,
    /// Tokens produced by autoregressive generation.
    pub generated_tokens: usize,
    /// High-water mark of resident KV-cache bytes (0 when no caches were
    /// bound; always ≤ measured peak since caches allocate on the run's
    /// tracker). Under either cache backend this is *true residency* —
    /// bytes held, which for the paged pool is blocks in use and for the
    /// contiguous cache coincides with reserved capacity.
    pub resident_kv_high_water_bytes: usize,
    /// Generations evicted to recompute under memory pressure (paged).
    pub evicted: usize,
    /// Prompt-prefix blocks deduplicated by sharing (paged).
    pub shared_prefix_hits: usize,
    /// KV blocks held at run end — the paged drain contract pins 0.
    pub final_blocks_in_use: usize,
    /// High-water mark of concurrently resident generations.
    pub max_concurrent_generations: usize,
    /// Requests shed with a structured reject reason (DESIGN.md §15).
    pub shed: usize,
    /// Requests whose tick deadline expired before they finished.
    pub deadline_missed: usize,
    /// Retry attempts scheduled after recoverable faults.
    pub retries: usize,
    /// Faults fired by the installed fault plan (0 without one).
    pub fault_injections: u64,
    /// Quiescent points the invariant auditor checked (0 when auditing
    /// was off).
    pub waves_audited: usize,
    /// Invariant violations collected — the chaos soak pins this at 0.
    pub audit_violations: usize,
    /// The auditor's violation messages, verbatim.
    pub audit_log: Vec<String>,
    /// Engine errors bucketed by stable kind string.
    pub errors_by_kind: HashMap<String, usize>,
    /// Graph dispatches spent on decode steps (looped: one per request
    /// per step; batched: one per wave group per step).
    pub decode_dispatches: usize,
    /// Waves that executed at least one decode entry.
    pub decode_waves: usize,
    /// Batched decode wave groups assembled (0 with `batch_decode` off).
    pub batched_decode_groups: usize,
    /// Requests shed while queued (never admitted) — the complement of
    /// the admitted-only wait percentiles.
    pub shed_wait: usize,
    /// Chunked-prefill slices executed across the run.
    pub prefill_slices: usize,
    /// Waves where a prefill slice and a decode step shared the wave.
    pub interleaved_waves: usize,
    /// Generations spilled to the simulated slow tier under stall
    /// pressure (spill tier, DESIGN.md §18; 0 with `spill_gbps` 0).
    pub kv_spills: usize,
    /// Parked KV tables restored into the pool.
    pub kv_restores: usize,
    /// Bytes moved fast → slow across all KV spills.
    pub kv_spill_bytes: usize,
    /// Bytes moved slow → fast across all KV restores.
    pub kv_restore_bytes: usize,
    /// Activation-spill traffic summed over executed wave entries
    /// (memory-planner spill tiers): bytes offloaded at spill points.
    pub spill_out_bytes: usize,
    /// Bytes copied back from the slow tier at restore points.
    pub spill_in_bytes: usize,
    /// Spill-script events executed (offload spills + all restores).
    pub spill_events: usize,
    /// Restores served by re-executing the producing node.
    pub spill_recomputes: usize,
    /// Time-to-first-token percentiles (queueing wait + prefill
    /// execution; zeros when nothing generated).
    pub ttft_p50_us: u64,
    pub ttft_p99_us: u64,
    /// Inter-token-latency percentiles — the decode-SLO number chunked
    /// prefill exists to bound (zeros below two emissions per stream).
    pub itl_p50_us: u64,
    pub itl_p99_us: u64,
    /// Inter-token gaps sampled across the run.
    pub itl_samples: usize,
    pub mean_us: u64,
    pub per_variant: HashMap<String, usize>,
    /// Full latency distributions behind the point percentiles above:
    /// mergeable log-bucket sketches (DESIGN.md §19), so batch drivers
    /// can combine runs without re-deriving percentiles from raw logs.
    pub latency_sketch: LatencySketch,
    pub wait_sketch: LatencySketch,
    pub prefill_sketch: LatencySketch,
    pub decode_sketch: LatencySketch,
    pub ttft_sketch: LatencySketch,
    pub itl_sketch: LatencySketch,
}

impl MetricsReport {
    /// Human-readable multi-line summary for CLI/examples.
    pub fn render(&self) -> String {
        let mut variants: Vec<_> = self.per_variant.iter().collect();
        variants.sort();
        let vstr = variants
            .iter()
            .map(|(k, v)| format!("{k}:{v}"))
            .collect::<Vec<_>>()
            .join(" ");
        let mut s = format!(
            "completed={} rejected={} preempted={} waves={} wall={:.2}s\n\
             throughput={:.2} req/s ({:.0} tok/s)\n\
             latency mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms\n\
             wait p50={:.2}ms p99={:.2}ms | plan cache {}h/{}m | peak {:.1} MiB\n\
             variants: {vstr}",
            self.completed,
            self.rejected,
            self.preempted,
            self.waves,
            self.wall_seconds,
            self.throughput_rps,
            self.throughput_tokens_s,
            self.mean_us as f64 / 1e3,
            self.p50_us as f64 / 1e3,
            self.p95_us as f64 / 1e3,
            self.p99_us as f64 / 1e3,
            self.wait_p50_us as f64 / 1e3,
            self.wait_p99_us as f64 / 1e3,
            self.cache_hits,
            self.cache_misses,
            self.measured_peak_bytes as f64 / (1 << 20) as f64,
        );
        if self.generated_tokens > 0 {
            s.push_str(&format!(
                "\ngenerated {} tokens in {} decode steps | prefill p50={:.2}ms p99={:.2}ms | \
                 decode p50={:.2}ms p99={:.2}ms | resident kv high-water {:.1} MiB | \
                 {} concurrent | evicted={} shared-prefix-hits={}",
                self.generated_tokens,
                self.decode_steps,
                self.prefill_p50_us as f64 / 1e3,
                self.prefill_p99_us as f64 / 1e3,
                self.decode_p50_us as f64 / 1e3,
                self.decode_p99_us as f64 / 1e3,
                self.resident_kv_high_water_bytes as f64 / (1 << 20) as f64,
                self.max_concurrent_generations,
                self.evicted,
                self.shared_prefix_hits,
            ));
            if self.decode_waves > 0 {
                s.push_str(&format!(
                    "\ndecode dispatches: {} over {} decode waves ({:.2}/wave, {} batched groups)",
                    self.decode_dispatches,
                    self.decode_waves,
                    self.decode_dispatches as f64 / self.decode_waves as f64,
                    self.batched_decode_groups,
                ));
            }
            if self.ttft_p99_us > 0 || self.itl_samples > 0 {
                s.push_str(&format!(
                    "\nslo: ttft p50={:.2}ms p99={:.2}ms | itl p50={:.2}ms p99={:.2}ms \
                     ({} gaps)",
                    self.ttft_p50_us as f64 / 1e3,
                    self.ttft_p99_us as f64 / 1e3,
                    self.itl_p50_us as f64 / 1e3,
                    self.itl_p99_us as f64 / 1e3,
                    self.itl_samples,
                ));
            }
            if self.prefill_slices > 0 {
                s.push_str(&format!(
                    "\nchunked prefill: {} slices, {} interleaved waves",
                    self.prefill_slices, self.interleaved_waves,
                ));
            }
            if self.kv_spills + self.kv_restores > 0 {
                s.push_str(&format!(
                    "\nspill tier: {} kv spills ({:.1} MiB out), {} restores ({:.1} MiB in)",
                    self.kv_spills,
                    self.kv_spill_bytes as f64 / (1 << 20) as f64,
                    self.kv_restores,
                    self.kv_restore_bytes as f64 / (1 << 20) as f64,
                ));
            }
        }
        if self.spill_events + self.spill_recomputes > 0 {
            s.push_str(&format!(
                "\nactivation spill: {} events ({:.1} MiB out, {:.1} MiB in), {} recomputes",
                self.spill_events,
                self.spill_out_bytes as f64 / (1 << 20) as f64,
                self.spill_in_bytes as f64 / (1 << 20) as f64,
                self.spill_recomputes,
            ));
        }
        let total_errors: usize = self.errors_by_kind.values().sum();
        if self.shed
            + self.shed_wait
            + self.deadline_missed
            + self.retries
            + self.waves_audited
            + total_errors
            > 0
            || self.fault_injections > 0
        {
            let mut kinds: Vec<_> = self.errors_by_kind.iter().collect();
            kinds.sort();
            let kstr = kinds
                .iter()
                .map(|(k, v)| format!("{k}:{v}"))
                .collect::<Vec<_>>()
                .join(" ");
            s.push_str(&format!(
                "\nrobustness: shed={} shed-wait={} deadline-missed={} retries={} \
                 faults-injected={} | audited {} waves, {} violations | errors: {}",
                self.shed,
                self.shed_wait,
                self.deadline_missed,
                self.retries,
                self.fault_injections,
                self.waves_audited,
                self.audit_violations,
                if kstr.is_empty() { "none".to_string() } else { kstr },
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_computed() {
        let mut r = Recorder::new();
        for i in 1..=100u64 {
            r.record("v", i * 1000, 64);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.completed, 100);
        // log-bucket sketch: mid quantiles answer the bucket lower bound,
        // at most 1/16 below the exact nearest-rank value (51_000 here)
        assert!((49_152..=51_000).contains(&rep.p50_us), "{}", rep.p50_us);
        assert!((88_000..=96_000).contains(&rep.p95_us), "{}", rep.p95_us);
        assert!(rep.p99_us >= rep.p95_us);
        assert_eq!(rep.p99_us, 100_000, "top rank answers the exact max");
        assert!(rep.throughput_rps > 99.0);
        assert_eq!(rep.per_variant["v"], 100);
        assert_eq!(rep.mean_us, 50_500, "mean is exact (sum/count)");
    }

    #[test]
    fn empty_run_safe() {
        let rep = Recorder::new().finish(Duration::from_millis(10));
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.p99_us, 0);
        assert_eq!(rep.wait_p99_us, 0);
    }

    #[test]
    fn wait_percentiles_computed() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        for w in [100u64, 200, 300, 400] {
            r.record_wait(w);
        }
        r.preempted = 2;
        r.cache_hits = 3;
        r.cache_misses = 1;
        r.measured_peak_bytes = 5 << 20;
        let rep = r.finish(Duration::from_secs(1));
        assert!(rep.wait_p50_us >= 100 && rep.wait_p50_us <= 300);
        assert_eq!(rep.wait_p99_us, 400);
        assert_eq!(rep.preempted, 2);
        assert_eq!(rep.cache_hits, 3);
        assert_eq!(rep.cache_misses, 1);
        let s = rep.render();
        assert!(s.contains("preempted=2"), "{s}");
        assert!(s.contains("3h/1m"), "{s}");
    }

    #[test]
    fn decode_breakdown_percentiles() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_prefill(4000);
        r.record_prefill(6000);
        for d in [100u64, 200, 300, 400] {
            r.record_decode(d);
        }
        r.observe_resident_kv(3 << 20);
        r.observe_resident_kv(1 << 20); // high-water keeps the max
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.generated_tokens, 4);
        assert_eq!(rep.decode_steps, 4);
        assert!(rep.prefill_p50_us >= 4000 && rep.prefill_p99_us <= 6000);
        assert!(rep.decode_p50_us >= 100 && rep.decode_p50_us <= 300);
        assert_eq!(rep.decode_p99_us, 400);
        assert!(rep.decode_p99_us >= rep.decode_p50_us);
        assert_eq!(rep.resident_kv_high_water_bytes, 3 << 20);
        let s = rep.render();
        assert!(s.contains("generated 4 tokens"), "{s}");
        assert!(s.contains("resident kv high-water"), "{s}");
    }

    #[test]
    fn prefill_only_run_renders_without_decode_line() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.generated_tokens, 0);
        assert_eq!(rep.decode_p99_us, 0);
        assert!(!rep.render().contains("generated"));
    }

    #[test]
    fn robustness_line_renders_only_when_active() {
        // A plain run must not mention the chaos machinery at all.
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        let quiet = r.finish(Duration::from_secs(1));
        assert_eq!(quiet.shed, 0);
        assert!(quiet.errors_by_kind.is_empty());
        assert!(!quiet.render().contains("robustness"), "{}", quiet.render());

        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.shed = 2;
        r.deadline_missed = 1;
        r.retries = 3;
        r.fault_injections = 5;
        r.waves_audited = 4;
        r.record_error("kernel_poisoned");
        r.record_error("kernel_poisoned");
        r.record_error("block_alloc");
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.errors_by_kind["kernel_poisoned"], 2);
        assert_eq!(rep.errors_by_kind["block_alloc"], 1);
        let s = rep.render();
        assert!(s.contains("shed=2"), "{s}");
        assert!(s.contains("deadline-missed=1"), "{s}");
        assert!(s.contains("retries=3"), "{s}");
        assert!(s.contains("faults-injected=5"), "{s}");
        assert!(s.contains("kernel_poisoned:2"), "{s}");
    }

    #[test]
    fn slo_percentiles_computed() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100); // makes the generation block render
        for t in [1000u64, 2000, 3000, 4000] {
            r.record_ttft(t);
        }
        for g in [10u64, 20, 30, 40, 400] {
            r.record_itl(g);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert!(rep.ttft_p50_us >= 1000 && rep.ttft_p50_us <= 3000);
        assert_eq!(rep.ttft_p99_us, 4000);
        assert!(rep.itl_p50_us >= 10 && rep.itl_p50_us <= 40);
        assert_eq!(rep.itl_p99_us, 400);
        assert_eq!(rep.itl_samples, 5);
        let s = rep.render();
        assert!(s.contains("ttft"), "{s}");
        assert!(s.contains("itl"), "{s}");
    }

    #[test]
    fn slo_line_absent_without_samples() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.ttft_p99_us, 0);
        assert_eq!(rep.itl_samples, 0);
        assert!(!rep.render().contains("slo:"), "{}", rep.render());
        assert!(!rep.render().contains("chunked prefill"), "{}", rep.render());
    }

    #[test]
    fn shed_wait_and_slice_counters_render() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        r.shed_wait = 3;
        r.prefill_slices = 7;
        r.interleaved_waves = 2;
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.shed_wait, 3);
        assert_eq!(rep.prefill_slices, 7);
        assert_eq!(rep.interleaved_waves, 2);
        let s = rep.render();
        assert!(s.contains("shed-wait=3"), "{s}");
        assert!(s.contains("7 slices"), "{s}");
        assert!(s.contains("2 interleaved waves"), "{s}");
    }

    #[test]
    fn spill_counters_render() {
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        r.kv_spills = 2;
        r.kv_restores = 1;
        r.kv_spill_bytes = 4 << 20;
        r.kv_restore_bytes = 2 << 20;
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.kv_spills, 2);
        assert_eq!(rep.kv_restores, 1);
        let s = rep.render();
        assert!(s.contains("2 kv spills"), "{s}");
        assert!(s.contains("1 restores"), "{s}");
        // and a run that never spilled must not mention the tier
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        r.record_decode(100);
        assert!(!r.finish(Duration::from_secs(1)).render().contains("spill tier"));
    }

    #[test]
    fn zero_denominator_ratios_stay_finite() {
        // Zero-length run: every ratio/percentile in the report divides
        // by a guarded denominator — nothing may render NaN or inf
        // (these strings would otherwise leak into BENCH_*.json).
        let rep = Recorder::new().finish(Duration::from_millis(0));
        assert!(rep.wall_seconds > 0.0, "wall clamped away from zero");
        assert!(rep.throughput_rps.is_finite());
        assert!(rep.throughput_tokens_s.is_finite());
        assert_eq!(rep.mean_us, 0);
        let s = rep.render();
        assert!(!s.contains("NaN") && !s.contains("inf"), "{s}");
    }

    #[test]
    fn sketch_small_values_and_top_rank_exact() {
        let mut s = LatencySketch::new();
        for v in [0u64, 3, 7, 15, 15, 2] {
            s.record(v);
        }
        assert_eq!(s.count(), 6);
        assert_eq!(s.min(), 0);
        assert_eq!(s.max(), 15);
        assert_eq!(s.sum(), 42);
        // values < 16 bucket exactly: every quantile is an exact sample
        assert_eq!(s.quantile(0.0), 0);
        assert_eq!(s.quantile(0.5), 3);
        assert_eq!(s.quantile(1.0), 15);
    }

    #[test]
    fn sketch_quantile_error_bounded() {
        let mut s = LatencySketch::new();
        for i in 1..=10_000u64 {
            s.record(i * 17 + 5);
        }
        for q in [0.1, 0.25, 0.5, 0.9, 0.95, 0.99] {
            let exact = ((10_000.0 - 1.0) * q).round() as u64 * 17 + 17 + 5;
            let got = s.quantile(q);
            // bucket lower bound (≤ exact) or, in the top occupied
            // bucket, the exact max (≥ exact) — either way within 1/16
            assert!(
                (got as f64 - exact as f64).abs() <= exact as f64 / 16.0 + 1.0,
                "q{q}: {got} more than 1/16 from exact {exact}"
            );
        }
        assert_eq!(s.quantile(1.0), 10_000 * 17 + 5, "top rank exact");
    }

    #[test]
    fn sketch_merge_matches_single_sketch() {
        let mut a = LatencySketch::new();
        let mut b = LatencySketch::new();
        let mut both = LatencySketch::new();
        for i in 0..500u64 {
            let v = i * 313 + 11;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), both.quantile(q), "q{q} diverged after merge");
        }
    }

    #[test]
    fn report_carries_sketches_behind_percentiles() {
        let mut r = Recorder::new();
        for i in 1..=50u64 {
            r.record("v", i * 100, 8);
        }
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.latency_sketch.count(), 50);
        assert_eq!(rep.latency_sketch.quantile(0.99), rep.p99_us);
        assert_eq!(rep.latency_sketch.mean(), rep.mean_us);
        assert!(rep.wait_sketch.is_empty());
    }

    #[test]
    fn activation_spill_counters_surface() {
        use crate::exec::ExecStats;
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        let stats = ExecStats {
            spill_out_bytes: 3 << 20,
            spill_in_bytes: 1 << 20,
            spill_events: 4,
            spill_recomputes: 2,
            ..ExecStats::default()
        };
        r.absorb_exec(&stats);
        r.absorb_exec(&stats);
        let rep = r.finish(Duration::from_secs(1));
        assert_eq!(rep.spill_events, 8);
        assert_eq!(rep.spill_out_bytes, 6 << 20);
        assert_eq!(rep.spill_in_bytes, 2 << 20);
        assert_eq!(rep.spill_recomputes, 4);
        let s = rep.render();
        assert!(s.contains("activation spill: 8 events"), "{s}");
        assert!(s.contains("4 recomputes"), "{s}");
        // a run with no activation spills must not mention them
        let mut r = Recorder::new();
        r.record("v", 10, 8);
        assert!(!r.finish(Duration::from_secs(1)).render().contains("activation spill"));
    }

    #[test]
    fn render_contains_key_fields() {
        let mut r = Recorder::new();
        r.record("gpt_dense_s64", 1500, 64);
        let rep = r.finish(Duration::from_secs(1));
        let s = rep.render();
        assert!(s.contains("completed=1"));
        assert!(s.contains("gpt_dense_s64:1"));
    }
}
