//! Scheduler decision explainability (DESIGN.md §19).
//!
//! Every admission-control decision the serve engine takes — admit,
//! reject, deepen, shed, backoff-skip, defer, stall-spill, stall-evict —
//! is recorded as an [`AdmissionExplain`]: the decision plus the *priced
//! numbers* that drove it (cost vs remaining vs budget, blocks needed vs
//! free). Records are emitted as trace instant events on the scheduler
//! lane, so the same stream feeds three consumers:
//!
//! * the Chrome trace (each decision is an `admission` instant in
//!   Perfetto, clickable next to the wave it happened in),
//! * the per-request lifecycle timeline ([`request_timeline`] — the
//!   "why was I rejected" log rendered as text), and
//! * the determinism tests (decisions are pure scheduling state, so the
//!   records must be identical at any pool width).

use crate::util::trace::{ArgV, Event, Trace, TraceScope};

/// One admission-control decision with its pricing context. Byte fields
/// are 0 when the decision never reached pricing (e.g. a deadline shed).
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionExplain {
    /// Engine clock tick the decision was taken on.
    pub tick: u64,
    /// Request id the decision applies to.
    pub request: usize,
    /// `admit` | `reject` | `deepen` | `shed` | `backoff` | `defer` |
    /// `spill` | `evict` | `restore`.
    pub decision: &'static str,
    /// Human-readable cause (`RejectReason` name, `"memory-wall"`,
    /// `"fits-device-not-wave"`, ...). Empty when the decision is its
    /// own explanation.
    pub reason: &'static str,
    /// Sequence bucket the request routed to (0 = never resolved).
    pub bucket: usize,
    /// Chunk depth the decision was priced at.
    pub depth: usize,
    /// Priced admission cost in bytes (activation + cache growth).
    pub cost_bytes: usize,
    /// Budget remaining in the wave when the decision was taken.
    pub remaining_bytes: usize,
    /// The device budget the cost was judged against.
    pub budget_bytes: usize,
    /// KV blocks the request needed this wave (paged backend).
    pub need_blocks: usize,
    /// KV blocks free in the pool when the decision was taken.
    pub free_blocks: usize,
}

impl AdmissionExplain {
    /// Record this decision as an `admission` instant event on `scope`
    /// (the scheduler lane).
    pub fn emit(&self, scope: &TraceScope) {
        scope.instant(
            "admission",
            vec![
                ("tick", ArgV::U(self.tick)),
                ("req", ArgV::U(self.request as u64)),
                ("decision", ArgV::S(self.decision.to_string())),
                ("reason", ArgV::S(self.reason.to_string())),
                ("bucket", ArgV::U(self.bucket as u64)),
                ("depth", ArgV::U(self.depth as u64)),
                ("cost", ArgV::U(self.cost_bytes as u64)),
                ("remaining", ArgV::U(self.remaining_bytes as u64)),
                ("budget", ArgV::U(self.budget_bytes as u64)),
                ("need_blocks", ArgV::U(self.need_blocks as u64)),
                ("free_blocks", ArgV::U(self.free_blocks as u64)),
            ],
        );
    }

    /// [`AdmissionExplain::emit`] through the engine's optional scope —
    /// the disabled path is one `None` branch.
    pub fn emit_opt(&self, scope: &Option<TraceScope>) {
        if let Some(s) = scope {
            self.emit(s);
        }
    }
}

/// Render the lifecycle of one request from a trace: every event that
/// mentions it (admission decisions, wave-entry spans, auditor
/// violations), in deterministic `(lane, seq)` order, as a compact text
/// timeline.
pub fn request_timeline(trace: &Trace, request: usize) -> String {
    let mut out = format!("req {request}:\n");
    let mut any = false;
    for e in trace.events() {
        if !e.mentions_request(request) {
            continue;
        }
        any = true;
        out.push_str(&render_line(&e));
    }
    if !any {
        out.push_str("  (no recorded events)\n");
    }
    out
}

/// Per-request timelines for every request id mentioned anywhere in the
/// trace, ascending by id.
pub fn timelines(trace: &Trace) -> String {
    let events = trace.events();
    let mut ids: Vec<usize> = Vec::new();
    for e in &events {
        for (k, v) in &e.args {
            match (*k, v) {
                ("req", ArgV::U(r)) => ids.push(*r as usize),
                ("reqs", ArgV::S(s)) => {
                    ids.extend(s.split(',').filter_map(|p| p.trim().parse::<usize>().ok()))
                }
                _ => {}
            }
        }
    }
    ids.sort_unstable();
    ids.dedup();
    let mut out = String::new();
    for id in ids {
        out.push_str(&request_timeline(trace, id));
    }
    out
}

fn render_line(e: &Event) -> String {
    let mut line = String::from("  ");
    // lead with the tick when the event recorded one
    if let Some(ArgV::U(t)) = e.args.iter().find(|(k, _)| *k == "tick").map(|(_, v)| v) {
        line.push_str(&format!("[tick {t}] "));
    }
    line.push_str(&e.name);
    for (k, v) in &e.args {
        if *k == "tick" {
            continue;
        }
        match v {
            ArgV::S(s) if s.is_empty() => continue,
            ArgV::U(x) => line.push_str(&format!(" {k}={x}")),
            ArgV::I(x) => line.push_str(&format!(" {k}={x}")),
            ArgV::F(x) => line.push_str(&format!(" {k}={x}")),
            ArgV::S(s) => line.push_str(&format!(" {k}={s}")),
        }
    }
    line.push('\n');
    line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::trace::{Trace, TraceHeader, LANE_ENGINE};

    fn sample() -> AdmissionExplain {
        AdmissionExplain {
            tick: 3,
            request: 7,
            decision: "reject",
            reason: "memory-wall",
            bucket: 32,
            depth: 2,
            cost_bytes: 4096,
            remaining_bytes: 1024,
            budget_bytes: 2048,
            need_blocks: 2,
            free_blocks: 1,
        }
    }

    #[test]
    fn emit_records_all_priced_numbers() {
        let t = Trace::new(TraceHeader::default());
        let s = t.scope(LANE_ENGINE);
        sample().emit(&s);
        let evs = t.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "admission");
        assert!(evs[0].mentions_request(7));
        let c = t.canonical();
        assert!(c.contains("decision=\"reject\""), "{c}");
        assert!(c.contains("reason=\"memory-wall\""), "{c}");
        assert!(c.contains("cost=4096"), "{c}");
        assert!(c.contains("free_blocks=1"), "{c}");
    }

    #[test]
    fn emit_opt_none_is_inert() {
        sample().emit_opt(&None);
    }

    #[test]
    fn timeline_renders_per_request() {
        let t = Trace::new(TraceHeader::default());
        let s = t.scope(LANE_ENGINE);
        sample().emit(&s);
        let mut admit = sample();
        admit.request = 8;
        admit.decision = "admit";
        admit.reason = "";
        admit.emit(&s);
        let tl = request_timeline(&t, 7);
        assert!(tl.starts_with("req 7:\n"), "{tl}");
        assert!(tl.contains("[tick 3] admission"), "{tl}");
        assert!(tl.contains("decision=reject"), "{tl}");
        assert!(!tl.contains("decision=admit"), "{tl}");
        let all = timelines(&t);
        assert!(all.contains("req 7:\n") && all.contains("req 8:\n"), "{all}");
        let none = request_timeline(&t, 99);
        assert!(none.contains("no recorded events"), "{none}");
    }
}
