//! L3 serving tier: router, batcher, memory-budget scheduler.
//!
//! The inference-serving context the paper motivates: requests with varying
//! sequence lengths arrive at a device with a fixed activation-memory
//! budget. Two backends share the queue/admission vocabulary:
//!
//! * [`engine::ServeEngine`] — the **continuous-batching engine** over the
//!   native compiler stack: arrival-ticked request queue, memory-aware
//!   admission priced by the estimator's [`crate::passes::CostQuote`]
//!   upper bounds, per-bucket compiled-plan caching, and preemption of
//!   oversized requests to deeper-chunked retries (DESIGN.md §11). This
//!   is the production path; it needs no AOT artifacts.
//! * [`Coordinator`] — the AOT/PJRT tier: routes each request to a
//!   sequence bucket, picks the cheapest-loss variant (dense → chunked(n)
//!   → fused) whose advertised activation fits, packs one-shot waves, and
//!   executes compiled artifacts. Kept for the JAX artifact workflow
//!   (`make artifacts`).
//!
//! Requests longer than any variant that fits are *rejected* — unless a
//! chunked variant "breaks the memory wall" (§4.2), which is exactly the
//! effect the serve example measures.

pub mod audit;
pub mod cache_manager;
pub mod engine;
pub mod explain;
pub mod metrics;
pub mod request;

pub use audit::{AuditReport, Auditor};
pub use cache_manager::CacheManager;
pub use explain::AdmissionExplain;
pub use engine::{
    batch_decode_default, greedy_argmax, pad_prompt, prefill_chunk_default, EngineConfig,
    EngineError, EngineResponse, PlanKind, RejectReason, ServeEngine,
};
pub use metrics::{LatencySketch, MetricsReport, Recorder};
pub use request::{
    generate_workload, open_loop_workload, poisson_workload, synthetic_workload, Request,
    RequestOutcome, Response,
};

use crate::runtime::{ArtifactMeta, Runtime};
use crate::util::error::{Context, Result};
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    /// Activation-memory budget in bytes (the device's headroom).
    pub budget_bytes: usize,
    /// Max requests per wave regardless of memory.
    pub max_batch: usize,
    pub model: String,
    /// Variant modes the router may use (e.g. `["dense"]` for the
    /// no-chunking baseline; empty = all modes).
    pub allowed_modes: Vec<String>,
    /// Kernel/chunk pool width while this worker executes waves
    /// (0 = inherit `AUTOCHUNK_THREADS` / machine default). A deployment
    /// running several coordinator workers per host sizes each one so the
    /// workers don't oversubscribe the cores.
    pub worker_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".into(),
            budget_bytes: 16 << 20,
            max_batch: 8,
            model: "gpt".into(),
            allowed_modes: Vec::new(),
            worker_threads: 0,
        }
    }
}

/// A wave of co-resident requests with chosen variants.
#[derive(Debug, Default)]
pub struct Wave {
    /// (request index, chosen tag, est bytes)
    pub entries: Vec<(usize, String, usize)>,
    pub total_bytes: usize,
}

/// The serving coordinator.
pub struct Coordinator {
    pub config: ServeConfig,
    runtime: Runtime,
}

impl Coordinator {
    pub fn new(config: ServeConfig) -> Result<Coordinator> {
        let runtime = Runtime::new(&config.artifacts_dir)
            .context("starting runtime for coordinator")?;
        Ok(Coordinator { config, runtime })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Smallest bucket that holds `seq_len` (None if longer than all).
    pub fn bucket_for(&self, seq_len: usize) -> Option<usize> {
        self.runtime
            .registry()
            .buckets(&self.config.model)
            .into_iter()
            .find(|&b| b >= seq_len)
    }

    /// Pick the variant for a request given `remaining` budget bytes:
    /// the fastest (highest-activation) one that fits. Returns None when
    /// even the most chunked variant exceeds the remaining budget.
    pub fn route(&self, seq_len: usize, remaining: usize) -> Option<ArtifactMeta> {
        let bucket = self.bucket_for(seq_len)?;
        let variants = self.runtime.registry().variants(&self.config.model, bucket);
        variants
            .into_iter()
            .filter(|m| {
                self.config.allowed_modes.is_empty()
                    || self.config.allowed_modes.iter().any(|a| *a == m.mode)
            })
            .find(|m| m.est_activation_bytes <= remaining)
            .cloned()
    }

    /// Greedy wave packing in arrival order: admit requests while their
    /// variant estimates fit the remaining budget (and max_batch).
    ///
    /// Variant choice uses the *full* budget, not the wave remainder:
    /// downgrading a request to a slower chunked variant merely to squeeze
    /// it into the current wave trades real speed for nothing (the next
    /// wave would have run it dense). A request whose full-budget variant
    /// doesn't fit the remainder closes the wave.
    pub fn plan_wave(&self, pending: &[&Request]) -> Wave {
        let mut wave = Wave::default();
        let mut remaining = self.config.budget_bytes;
        for (idx, req) in pending.iter().enumerate() {
            if wave.entries.len() >= self.config.max_batch {
                break;
            }
            match self.route(req.seq_len, self.config.budget_bytes) {
                Some(meta) if meta.est_activation_bytes <= remaining => {
                    remaining -= meta.est_activation_bytes;
                    wave.total_bytes += meta.est_activation_bytes;
                    wave.entries
                        .push((idx, meta.tag.clone(), meta.est_activation_bytes));
                }
                // fits the device but not this wave: close the wave
                Some(_) => break,
                // can never fit: leave for reject handling upstream
                None => break,
            }
        }
        wave
    }

    /// Serve a closed workload to completion; returns responses + metrics.
    pub fn serve(&mut self, requests: &[Request]) -> Result<(Vec<Response>, MetricsReport)> {
        let width = match self.config.worker_threads {
            0 => crate::util::pool::num_threads(),
            n => n,
        };
        crate::util::pool::with_threads(width, || self.serve_inner(requests))
    }

    fn serve_inner(&mut self, requests: &[Request]) -> Result<(Vec<Response>, MetricsReport)> {
        let t0 = Instant::now();
        let mut recorder = Recorder::new();
        let mut responses: Vec<Response> = Vec::with_capacity(requests.len());
        let mut queue: Vec<&Request> = requests.iter().collect();

        while !queue.is_empty() {
            let wave = self.plan_wave(&queue);
            if wave.entries.is_empty() {
                // head request cannot fit under any variant: reject it
                let req = queue.remove(0);
                recorder.rejected += 1;
                responses.push(Response {
                    id: req.id,
                    outcome: RequestOutcome::Rejected,
                    variant: String::new(),
                    latency_us: 0,
                });
                continue;
            }
            // Execute the wave (serially; CPU PJRT parallelizes inside the
            // op; the wave is the co-residency unit for memory accounting).
            let mut taken = Vec::new();
            for (idx, tag, _est) in &wave.entries {
                let req = queue[*idx];
                let started = Instant::now();
                let out = self.runtime.run(tag, &req.tokens)?;
                let latency_us = started.elapsed().as_micros() as u64
                    + req.arrival_offset_us.saturating_sub(0);
                debug_assert!(out.iter().all(|x| x.is_finite()));
                recorder.record(tag, latency_us, req.seq_len);
                responses.push(Response {
                    id: req.id,
                    outcome: RequestOutcome::Completed,
                    variant: tag.clone(),
                    latency_us,
                });
                taken.push(*idx);
            }
            // remove served entries (descending index order)
            taken.sort_unstable_by(|a, b| b.cmp(a));
            for idx in taken {
                queue.remove(idx);
            }
            recorder.waves += 1;
        }

        let report = recorder.finish(t0.elapsed());
        Ok((responses, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn have_artifacts() -> bool {
        std::path::Path::new(&format!("{}/gpt_dense_s64.meta", artifacts_dir())).exists()
    }

    fn coordinator(budget: usize) -> Coordinator {
        Coordinator::new(ServeConfig {
            artifacts_dir: artifacts_dir(),
            budget_bytes: budget,
            max_batch: 8,
            model: "gpt".into(),
            ..ServeConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn bucket_selection() {
        if !have_artifacts() {
            return;
        }
        let c = coordinator(64 << 20);
        assert_eq!(c.bucket_for(10), Some(64));
        assert_eq!(c.bucket_for(64), Some(64));
        assert_eq!(c.bucket_for(65), Some(128));
        assert_eq!(c.bucket_for(100_000), None);
    }

    #[test]
    fn generous_budget_routes_dense() {
        if !have_artifacts() {
            return;
        }
        let c = coordinator(1 << 30);
        let m = c.route(200, 1 << 30).unwrap();
        assert_eq!(m.mode, "dense");
        assert_eq!(m.seq, 256);
    }

    #[test]
    fn tight_budget_falls_back_to_chunked_or_fused() {
        if !have_artifacts() {
            return;
        }
        let c = coordinator(1 << 30);
        let dense = c
            .runtime
            .registry()
            .get("gpt_dense_s256")
            .unwrap()
            .est_activation_bytes;
        // just below dense: must pick a memory-lighter variant
        let m = c.route(200, dense - 1).unwrap();
        assert_ne!(m.mode, "dense");
        assert!(m.est_activation_bytes < dense);
    }

    #[test]
    fn zero_budget_rejects() {
        if !have_artifacts() {
            return;
        }
        let c = coordinator(1 << 30);
        assert!(c.route(200, 0).is_none());
    }

    #[test]
    fn wave_respects_budget_invariant() {
        if !have_artifacts() {
            return;
        }
        // randomized packing invariant (hand-rolled property test)
        let mut state = 0x12345678u64;
        let mut rnd = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for trial in 0..20 {
            let budget = (1 << 20) + (rnd() % (64 << 20)) as usize;
            let c = coordinator(budget);
            let reqs: Vec<Request> = (0..12)
                .map(|i| Request::new(i, (rnd() % 256 + 1) as usize, (rnd() % 512) as i32))
                .collect();
            let refs: Vec<&Request> = reqs.iter().collect();
            let wave = c.plan_wave(&refs);
            assert!(
                wave.total_bytes <= budget,
                "trial {trial}: wave {} > budget {budget}",
                wave.total_bytes
            );
            assert!(wave.entries.len() <= c.config.max_batch);
            // entries must reference distinct queue slots
            let mut idxs: Vec<usize> = wave.entries.iter().map(|e| e.0).collect();
            idxs.dedup();
            assert_eq!(idxs.len(), wave.entries.len());
        }
    }

    // Serving waves executes artifacts, which needs the real PJRT runtime.
    #[cfg(feature = "pjrt")]
    #[test]
    fn serve_completes_or_rejects_every_request() {
        if !have_artifacts() {
            return;
        }
        let mut c = coordinator(8 << 20);
        let reqs = synthetic_workload(10, 64, 256, 99);
        let (responses, report) = c.serve(&reqs).unwrap();
        assert_eq!(responses.len(), reqs.len());
        let completed = responses
            .iter()
            .filter(|r| r.outcome == RequestOutcome::Completed)
            .count();
        assert_eq!(completed + report.rejected, reqs.len());
        assert_eq!(report.completed, completed);
        // every completed request ran some variant
        for r in &responses {
            if r.outcome == RequestOutcome::Completed {
                assert!(!r.variant.is_empty());
            }
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn chunked_variants_break_the_memory_wall() {
        if !have_artifacts() {
            return;
        }
        // budget below dense-256 but above chunked-256
        let reg = coordinator(1 << 30);
        let dense = reg
            .runtime
            .registry()
            .get("gpt_dense_s256")
            .unwrap()
            .est_activation_bytes;
        let chunk = reg
            .runtime
            .registry()
            .get("gpt_chunked_s256_n8")
            .unwrap()
            .est_activation_bytes;
        assert!(chunk < dense);
        let budget = (chunk + dense) / 2;

        let mut with_chunk = coordinator(budget);
        let mut without = coordinator(budget);
        without.config.allowed_modes = vec!["dense".into()];

        let reqs = synthetic_workload(4, 200, 256, 7);
        let (_, rep_with) = with_chunk.serve(&reqs).unwrap();
        let (_, rep_without) = without.serve(&reqs).unwrap();
        assert_eq!(rep_with.rejected, 0, "chunked variants should fit");
        assert!(
            rep_without.rejected > 0,
            "without chunking these must not fit"
        );
    }
}
