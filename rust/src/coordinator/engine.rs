//! Continuous-batching serve engine over the native compiler stack.
//!
//! The PR-1 `Coordinator` served a *closed* workload through AOT/PJRT
//! artifacts in one shot. This engine is the production shape the paper's
//! runtime half points at (DESIGN.md §11, §13):
//!
//! * **request queue with arrival ticks** — an open-loop trace replayed on
//!   a deterministic virtual clock, so admission pressure is part of the
//!   workload and results are machine-independent;
//! * **memory-aware admission** — each wave is packed greedily against the
//!   global `budget_bytes` by per-request prices: the estimator's
//!   [`CostQuote`] (or the memory planner's exact bound in arena mode)
//!   *plus*, for generation requests, the full-capacity KV-cache bytes
//!   the request will pin for its lifetime;
//! * **autoregressive generation** — a `Request { max_new_tokens > 0 }`
//!   runs one chunk-planned causal prefill that seeds a [`KvCache`], then
//!   decode steps scheduled in the same memory-aware waves: each step is
//!   priced `planned_peak(decode@past)` on top of Σ resident cache bytes,
//!   so `planned_peak + resident_kv_bytes(s)` is exactly what admission
//!   charges as caches grow. Finished requests evict their caches and
//!   resident bytes return to the pool;
//! * **per-bucket compiled-plan caching** — a (kind, seq-bucket, depth)
//!   triple is compiled once and the resulting [`PlanHandle`] is shared by
//!   every subsequent request in that bucket. Decode plans are cached per
//!   (bucket, cache-length) — decode graphs are parameterized by `past` —
//!   so steady-state decoding is all cache hits;
//! * **preemption instead of rejection** — a request whose price exceeds
//!   the budget is requeued (at the head of its priority class) for a deeper-chunked
//!   recompile; only when the deepest level still does not fit is it
//!   rejected ("the memory wall");
//! * **paged KV caches** (`block_tokens > 0`, DESIGN.md §14) — generation
//!   caches live in a refcounted block pool
//!   ([`crate::coordinator::cache_manager::CacheManager`]): admission
//!   prices residency at blocks in use plus the blocks a wave allocates
//!   (grow-as-you-go, not bucket-capacity reservation), identical prompt
//!   prefixes share blocks (copy-on-write on divergence), and a
//!   budget-stalled decode set evicts a victim's blocks and re-queues it
//!   for re-prefill recompute — bitwise-stream-preserving by decode
//!   parity;
//! * **graceful degradation under faults** (DESIGN.md §15) — an installed
//!   [`FaultPlan`] injects deterministic failures (allocation trips,
//!   poisoned kernels, latency spikes); each wave entry runs panic-
//!   isolated, failures surface as typed [`EngineError`]s that fail only
//!   their own request's attempt, retries back off exponentially on the
//!   virtual clock up to `max_retries`, and per-request `deadline_ticks`
//!   / priority classes turn overload into structured load shedding
//!   ([`RejectReason`]) — never a panic, never a silent drop. The
//!   optional [`Auditor`] proves conservation invariants between waves.
//!
//! Determinism contract: at `AUTOCHUNK_THREADS=1` the engine's responses
//! are bitwise identical to the legacy back-to-back path
//! ([`ServeEngine::serve_serial`]); at any width they remain bitwise
//! identical because every parallel region in the stack decomposes over
//! disjoint output slabs (DESIGN.md §8). Generated token streams are part
//! of that contract: decode logits are bitwise identical to re-running
//! full prefill at the grown length (`rust/tests/decode_parity.rs`).

use crate::coordinator::audit::Auditor;
use crate::coordinator::cache_manager::{CacheManager, SpilledTable};
use crate::coordinator::explain::AdmissionExplain;
use crate::coordinator::metrics::{MetricsReport, Recorder};
use crate::coordinator::request::{Request, RequestOutcome};
use crate::exec::{random_params, ExecStats};
use crate::ir::Graph;
use crate::models::{self, GptConfig};
use crate::passes::select::placement_cost_us;
use crate::passes::{autochunk, estimate, AutoChunkConfig, CostQuote, SpillParams};
use crate::plan::{ExecOptions, PlanHandle};
use crate::runtime::{ArtifactMeta, Registry};
use crate::tensor::{numel, BlockTable, DType, KvCache, MemoryTracker, Tensor};
use crate::util::error::Result;
use crate::util::fault::{silence_injected_panics, FaultPlan, FaultScope, InjectedFault};
use crate::util::pool;
use crate::util::trace::{self, ArgV, Trace, TraceHeader, TraceScope};
use std::cmp::Reverse;
use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Configuration of the continuous-batching engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model family: `gpt` | `gpt-fused` | `vit` | `evoformer` | `unet`.
    /// Generation (`max_new_tokens > 0`) requires a gpt family.
    pub model: String,
    /// Global activation-memory budget (bytes) each wave is packed under.
    /// Resident KV caches count against it for their whole lifetime.
    pub budget_bytes: usize,
    /// Max co-resident wave entries (prefills + decode steps) regardless
    /// of memory.
    pub max_batch: usize,
    /// Sequence buckets (ascending); a request routes to the smallest
    /// bucket that holds its *total* footprint ([`Request::total_len`]:
    /// prompt plus fed-back generated positions — the KV cache is
    /// capacity-shaped at the bucket). Per-model scale knob (tokens,
    /// patches, residues, image side).
    pub buckets: Vec<usize>,
    /// Pool width while serving (0 = inherit `AUTOCHUNK_THREADS`).
    pub worker_threads: usize,
    /// How many deeper-chunked recompiles an oversized request may retry
    /// before rejection. Level `d ≥ 1` compiles at a `baseline >> d`
    /// target; level 0 is the dense (unchunked) plan.
    pub max_deepen: usize,
    /// Virtual duration of one queue tick (metrics only).
    pub tick_us: u64,
    /// Serve through the planned-allocation arena executor and price
    /// admission with the memory planner's *exact* `admission_bytes`
    /// instead of the pessimistic quote (the quote stays a cross-check
    /// ceiling). Defaults to the `AUTOCHUNK_ARENA` env flag — the CI
    /// matrix's second leg.
    pub use_arena: bool,
    /// Batched decode (DESIGN.md §16): assemble each wave's decode steps
    /// into one fused `[n, d]` graph per sequence bucket — one model
    /// dispatch (plus one LM-head dispatch) per wave instead of one per
    /// request — with token streams **bitwise identical** to the looped
    /// per-request path (`rust/tests/decode_batched_parity.rs`). Wave
    /// widths round up to the next power of two so warm waves of a shape
    /// bucket reuse compiled plans and arenas; padding rows are inert
    /// (token 0 at position 0 against all-zero caches). Defaults to the
    /// `AUTOCHUNK_BATCH_DECODE` env flag — a CI matrix axis.
    pub batch_decode: bool,
    /// Chunked prefill (Sarathi-style, DESIGN.md §17): slice budget in
    /// prompt tokens. `0` (the default) runs each prefill monolithically
    /// in one wave entry. When `> 0`, a generative prefill longer than
    /// this is split into `ceil(plen / chunk)` slices
    /// ([`models::gpt_prefill_chunk`]) scheduled *between* decode waves
    /// — decode inter-token latency stays bounded by one slice instead
    /// of one whole prefill — with the first token bitwise identical to
    /// the monolithic path. A mid-prefill generation that loses the
    /// per-wave budget race simply pauses: it keeps its cache (blocks,
    /// in paged mode) and resumes at its exact position, and under
    /// stall pressure it spills through the ordinary eviction path.
    /// Defaults to the `AUTOCHUNK_PREFILL_CHUNK` env knob.
    pub prefill_chunk_tokens: usize,
    /// Paged KV-cache mode (DESIGN.md §14): block size in tokens. `0`
    /// (the default) keeps the legacy contiguous full-capacity caches.
    /// When `> 0`, generation caches live in a refcounted block pool:
    /// admission prices resident state at *blocks in use* plus the blocks
    /// a wave will allocate — grow-as-you-go instead of reserving bucket
    /// capacity up front — prompt-prefix blocks are shared across
    /// requests, and memory-pressure stalls evict a victim's blocks and
    /// re-queue it for chunk-planned re-prefill recompute.
    pub block_tokens: usize,
    /// Paged mode: cap on pool blocks (0 = derive from `budget_bytes`).
    pub pool_blocks: usize,
    /// Paged mode: evictions one request may survive before rejection.
    pub max_evictions: usize,
    /// Fault retries (injected faults, poisons, stray panics) one
    /// request may consume — each retry backs off exponentially on the
    /// virtual clock — before structured rejection
    /// ([`RejectReason::RetriesExhausted`]).
    pub max_retries: usize,
    /// Simulated slow-tier bandwidth in GB/s for spill/recompute
    /// placement (DESIGN.md §18). `0.0` (the default) disables the tier
    /// entirely: plans, arena high-waters, and token streams stay
    /// bitwise identical to the pre-spill engine. When `> 0`, compiled
    /// plans may park cold intermediates in the slow tier (priced at
    /// `bytes / spill_gbps` against recompute FLOPs), and a
    /// budget-stalled paged decode parks a victim's KV blocks there
    /// instead of dropping them for re-prefill recompute —
    /// restore-on-touch, priced through block admission. Defaults to
    /// the `AUTOCHUNK_SPILL_GBPS` env knob.
    pub spill_gbps: f64,
    /// Deterministic chaos harness (DESIGN.md §15): when installed, the
    /// named injection sites roll seeded dice and the engine must
    /// degrade gracefully instead of panicking. `None` (the default)
    /// keeps every site a single predictable branch.
    pub faults: Option<Arc<FaultPlan>>,
    /// Run the engine invariant auditor after every wave (and at drain).
    /// Violations are collected on the metrics report, never panicked.
    pub audit: bool,
    /// Record a structured trace of each serve call (DESIGN.md §19):
    /// scheduler decisions, compile/wave/node spans, KV-cache events,
    /// and the per-wave memory timeline, retrievable afterwards via
    /// [`ServeEngine::take_trace`]. Also forced on when
    /// `AUTOCHUNK_TRACE=<path>` is set (which additionally writes the
    /// Chrome trace-event JSON to `<path>`). `false` — the default —
    /// keeps every instrumentation site a single `None` branch with no
    /// allocation, locking, or clock read.
    pub trace: bool,
    /// Compiler options for the per-bucket chunk search.
    pub compile: AutoChunkConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "gpt".into(),
            budget_bytes: 64 << 20,
            max_batch: 8,
            buckets: vec![64, 128, 256],
            worker_threads: 0,
            max_deepen: 5,
            tick_us: 500,
            use_arena: crate::plan::arena_default(),
            batch_decode: batch_decode_default(),
            prefill_chunk_tokens: prefill_chunk_default(),
            block_tokens: 0,
            pool_blocks: 0,
            max_evictions: 3,
            max_retries: 8,
            spill_gbps: spill_gbps_default(),
            faults: None,
            audit: false,
            trace: false,
            compile: AutoChunkConfig::default(),
        }
    }
}

/// Typed failure of one engine operation (DESIGN.md §15). Retryable
/// variants fail a single request *attempt* — the coordinator backs the
/// request off and retries or load-sheds it; the rest are engine
/// invariant breaches that abort the whole serve call.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The block pool had no free block when one was needed.
    PoolExhausted { free: usize },
    /// A paged cache was touched while no manager was live (engine bug).
    MissingManager,
    /// A wave entry and its result disagreed on kind (engine bug).
    WaveMismatch,
    /// Stall eviction ran with no generation to evict (engine bug).
    StallWithoutGeneration,
    /// A generation reached cache seeding on a non-gpt model (engine
    /// bug — admission guards this).
    NonGptGeneration,
    /// The chaos harness fired at a named injection site.
    Injected { site: &'static str },
    /// A kernel produced a non-finite result (poisoned output).
    KernelPoisoned,
    /// A wave entry panicked with a payload the engine does not model.
    Panic(String),
}

impl EngineError {
    /// Stable counter key for `errors_by_kind` (injected faults report
    /// their site name).
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::PoolExhausted { .. } => "pool_exhausted",
            EngineError::MissingManager => "missing_manager",
            EngineError::WaveMismatch => "wave_mismatch",
            EngineError::StallWithoutGeneration => "stall_without_generation",
            EngineError::NonGptGeneration => "non_gpt_generation",
            EngineError::Injected { site } => site,
            EngineError::KernelPoisoned => "kernel_poisoned",
            EngineError::Panic(_) => "panic",
        }
    }

    /// Failures of one attempt (faults, poisons, stray panics, pool
    /// pressure) are retryable; invariant breaches are not.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            EngineError::PoolExhausted { .. }
                | EngineError::Injected { .. }
                | EngineError::KernelPoisoned
                | EngineError::Panic(_)
        )
    }

    /// Map a caught panic payload back to a typed error: injected
    /// faults carry their site; anything else keeps its message.
    fn from_panic(payload: Box<dyn std::any::Any + Send>) -> EngineError {
        match payload.downcast::<InjectedFault>() {
            Ok(f) => EngineError::Injected { site: f.site.name() },
            Err(p) => {
                let msg = if let Some(s) = p.downcast_ref::<&str>() {
                    (*s).to_string()
                } else if let Some(s) = p.downcast_ref::<String>() {
                    s.clone()
                } else {
                    "non-string panic payload".to_string()
                };
                EngineError::Panic(msg)
            }
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::PoolExhausted { free } => {
                write!(f, "kv block pool exhausted ({free} free)")
            }
            EngineError::MissingManager => write!(f, "paged cache without a manager"),
            EngineError::WaveMismatch => write!(f, "wave entry/result kind mismatch"),
            EngineError::StallWithoutGeneration => {
                write!(f, "stall eviction with no generations")
            }
            EngineError::NonGptGeneration => {
                write!(f, "generation reached seeding on a non-gpt model")
            }
            EngineError::Injected { site } => write!(f, "injected fault at site '{site}'"),
            EngineError::KernelPoisoned => write!(f, "kernel produced a non-finite output"),
            EngineError::Panic(msg) => write!(f, "wave entry panicked: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Why a request was load-shed (structured rejection — never a silent
/// drop). Carried on [`EngineResponse::reason`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RejectReason {
    /// Longer than every configured bucket.
    TooLong,
    /// Generation on a non-gpt model, or an empty prompt.
    NotGenerable,
    /// The paged pool can never hold the request, even running alone.
    PoolTooSmall,
    /// The irreducible floor (cache + LM head) exceeds the budget.
    BudgetFloor,
    /// The deepest chunk plan still does not fit the budget.
    MemoryWall,
    /// Evicted more than `max_evictions` times (thrashing).
    EvictionLimit,
    /// Fault retries exhausted (`max_retries`).
    RetriesExhausted,
    /// `deadline_ticks` expired before completion.
    DeadlineMissed,
}

impl RejectReason {
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::TooLong => "too_long",
            RejectReason::NotGenerable => "not_generable",
            RejectReason::PoolTooSmall => "pool_too_small",
            RejectReason::BudgetFloor => "budget_floor",
            RejectReason::MemoryWall => "memory_wall",
            RejectReason::EvictionLimit => "eviction_limit",
            RejectReason::RetriesExhausted => "retries_exhausted",
            RejectReason::DeadlineMissed => "deadline_missed",
        }
    }
}

/// Which compiled graph a plan-cache entry holds (DESIGN.md §13).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum PlanKind {
    /// Legacy prefill-only request graph (the model as-is).
    Prefill,
    /// Causal prefill emitting the KV-cache seed (generation path).
    PrefillKv,
    /// One chunked-prefill slice: `len` prompt rows at positions
    /// `past..past+len` against the cached prefix (DESIGN.md §17). Like
    /// [`PlanKind::Decode`], parameterized by position, so warm slices
    /// at a recurring `(past, len)` are plan-cache hits.
    PrefillChunk { past: usize, len: usize },
    /// One decode step against a cache of logical length `past`.
    Decode { past: usize },
    /// One decode step for `width` stacked requests (DESIGN.md §16).
    /// Ragged `past` is graph *data*, not shape — one plan serves every
    /// cache-length mix at a wave-width bucket.
    DecodeBatched { width: usize },
    /// Hidden-row → logits head (token selection; length-independent).
    LmHead,
    /// Batched head: `[width, d] → [width, vocab]` over the same
    /// pre-transposed `wteᵀ` as [`PlanKind::LmHead`].
    LmHeadBatched { width: usize },
}

/// The engine's answer for one request. Carries the full model output so
/// determinism can be asserted bitwise against the serial path.
#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: usize,
    pub outcome: RequestOutcome,
    /// Sequence bucket the request was served in (0 when rejected).
    pub bucket: usize,
    /// Chunk-deepening level of the plan that served it.
    pub depth: usize,
    /// Tag of the cached plan (empty when rejected).
    pub plan_tag: String,
    /// Queueing delay in ticks between arrival and admission.
    pub wait_ticks: u64,
    pub latency_us: u64,
    /// Flattened first model output: final hidden states for prefill-only
    /// requests, the *last step's logits* for generation (empty when
    /// rejected).
    pub output: Vec<f32>,
    /// Generated token ids (empty for prefill-only requests).
    pub tokens: Vec<i32>,
    /// Decode steps executed (generated tokens beyond the prefill's).
    pub decode_steps: usize,
    /// Structured load-shedding reason (Some iff rejected).
    pub reason: Option<RejectReason>,
    /// True when a destructive injected fault touched any attempt of
    /// this request — the chaos soak excludes these from its bitwise
    /// comparison against a fault-free run.
    pub fault_touched: bool,
    /// Virtual tick at which the engine settled this request — completion
    /// or structured rejection. Makes shedding *promptness* observable:
    /// a deadline-missed request must carry a tick near its expiry, not
    /// the tick some unrelated long generation finally freed a slot
    /// (the regression `queued_request_sheds_at_deadline_even_when_batch_is_full`).
    pub finished_tick: u64,
}

impl EngineResponse {
    fn rejected(id: usize, depth: usize, reason: RejectReason, clock: u64) -> EngineResponse {
        EngineResponse {
            id,
            outcome: RequestOutcome::Rejected,
            bucket: 0,
            depth,
            plan_tag: String::new(),
            wait_ticks: 0,
            latency_us: 0,
            output: Vec::new(),
            tokens: Vec::new(),
            decode_steps: 0,
            reason: Some(reason),
            fault_touched: false,
            finished_tick: clock,
        }
    }
}

/// A queued request: its index into the workload plus the deepening level
/// the next admission attempt will use, how many paged-mode evictions it
/// has survived, how many fault retries it has consumed, and the earliest
/// tick its next attempt may run (exponential backoff; 0 = immediately).
#[derive(Clone, Copy, Debug)]
struct Pending {
    idx: usize,
    depth: usize,
    evictions: usize,
    retries: usize,
    not_before: u64,
}

/// A generation's cache backend: the legacy contiguous full-capacity
/// cache, or a block table into the run's paged pool (DESIGN.md §14).
enum GenCache {
    Whole(KvCache),
    Paged(BlockTable),
    /// Parked in the simulated slow tier (paged mode with
    /// `spill_gbps > 0`, DESIGN.md §18): the generation keeps its exact
    /// stream state (`tokens`, `past`, `plen`) in place and waits for
    /// the restore pre-pass to buy its blocks back — no recompute. A
    /// spilled generation is never admitted to a wave.
    Spilled(SpilledTable),
}

/// Decode state a paged-mode eviction preserves so a re-queued request
/// resumes its exact stream: tokens generated so far (re-prefill runs
/// over prompt ++ all-but-the-last of these — the last is the next input
/// token, never yet cached), the decode-step count for metrics, and the
/// last emission instant so resumed streams keep honest inter-token
/// latencies.
struct ResumeState {
    tokens: Vec<i32>,
    decode_steps: usize,
    last_emit: Option<Instant>,
}

/// An admitted generation: its cache and token stream. With chunked
/// prefill a generation is admitted *before* its prompt is cached —
/// while `past < plen` it is an in-progress (possibly paused) prefill
/// with `tokens` still empty; decode starts once the final slice lands
/// the first token (DESIGN.md §17).
struct GenState {
    idx: usize,
    bucket: usize,
    depth: usize,
    plan_tag: String,
    cache: GenCache,
    /// Generated ids so far (the last one's K/V are not yet cached — it
    /// is the next decode step's input token). Empty while prefilling.
    tokens: Vec<i32>,
    /// Cache logical length == absolute position of the next input token
    /// (or of the next prefill slice, while `past < plen`).
    past: usize,
    /// Effective prompt length: prefill is complete once `past == plen`.
    plen: usize,
    /// Effective prompt tokens while prefilling (cleared at completion);
    /// slice `k` feeds `ptoks[past..past+n]` to the slice graph.
    ptoks: Vec<i32>,
    /// Resume payload carried through a chunked re-prefill: restored
    /// into `tokens` when the final slice completes.
    pending_resume: Option<ResumeState>,
    last_logits: Vec<f32>,
    wait_ticks: u64,
    latency_us: u64,
    decode_steps: usize,
    /// Wall-clock instant of the last token emission (first token or
    /// decode step) — the inter-token-latency clock.
    last_emit: Option<Instant>,
    /// Paged-mode evictions this request has survived so far.
    evictions: usize,
    /// Fault retries this request has consumed so far.
    retries: usize,
}

impl GenState {
    fn next_input_token(&self) -> i32 {
        debug_assert!(!self.tokens.is_empty(), "generation holds at least the prefill token");
        self.tokens.last().copied().unwrap_or(0)
    }
}

/// One admitted wave entry (handles resolved before execution so the
/// parallel section never touches the plan cache).
enum WaveEntry {
    /// A prefill: `lm` is bound iff the request generates.
    Prefill {
        p: Pending,
        bucket: usize,
        h: PlanHandle,
        lm: Option<PlanHandle>,
        /// Effective prompt for a generative request: the request's
        /// tokens, extended with previously generated ones when this is a
        /// post-eviction re-prefill. Empty for non-generative requests.
        ptoks: Vec<i32>,
        /// Paged-mode resume payload (Some iff this prefill recomputes an
        /// evicted generation).
        resumed: Option<ResumeState>,
    },
    /// One chunked-prefill slice for `gens[gi]`: `n` prompt rows at
    /// `gens[gi].past`. `lm` is bound iff this is the final slice (the
    /// hidden row at `plen − 1` selects the first token).
    PrefillSlice {
        gi: usize,
        n: usize,
        h: PlanHandle,
        lm: Option<PlanHandle>,
    },
    /// One decode step for `gens[gi]`.
    Decode {
        gi: usize,
        h: PlanHandle,
        lm: PlanHandle,
    },
    /// One *batched* decode step covering `gis` (indices into `gens`,
    /// all in the same sequence bucket), stacked into one fused graph of
    /// `width ≥ gis.len()` rows — rows beyond the members are inert
    /// padding (DESIGN.md §16).
    DecodeBatched {
        gis: Vec<usize>,
        h: PlanHandle,
        lm: PlanHandle,
        width: usize,
    },
}

/// Result of one executed wave entry. A `Step` is either a generation
/// prefill or a decode step — the paired [`WaveEntry`] discriminates.
/// `stats` is the main execute's [`ExecStats`]: the auditor checks its
/// `arena_peak_bytes` against the planner's exact peak, and the recorder
/// absorbs its spill-tier traffic counters into the metrics report.
enum WaveOut {
    Plain {
        latency_us: u64,
        out: Vec<f32>,
        stats: ExecStats,
    },
    Step {
        latency_us: u64,
        outs: Vec<Tensor>,
        logits: Vec<f32>,
        token: i32,
        stats: ExecStats,
    },
    /// One batched decode step: `outs` holds the stacked graph outputs
    /// (`[hidden [w,d], k_new [h,w,dh], v_new, …]`); `logits`/`tokens`
    /// carry one row per *member* (padding rows already dropped), in
    /// `gis` order.
    StepBatch {
        latency_us: u64,
        outs: Vec<Tensor>,
        logits: Vec<Vec<f32>>,
        tokens: Vec<i32>,
        stats: ExecStats,
    },
    /// One chunked-prefill slice: `outs` is the slice graph's output list
    /// (`[hidden [n,d], k_new [h,n,dh], v_new, …]`); `logits`/`token` are
    /// bound iff this was the final slice.
    Slice {
        latency_us: u64,
        outs: Vec<Tensor>,
        logits: Option<Vec<f32>>,
        token: Option<i32>,
        stats: ExecStats,
    },
}

/// Did this wave result carry a non-finite float anywhere a downstream
/// consumer reads? Only screened when the chaos harness is installed —
/// a poisoned kernel must fail its own request, not corrupt the stream.
fn wave_out_poisoned(out: &WaveOut) -> bool {
    match out {
        WaveOut::Plain { out, .. } => out.iter().any(|x| !x.is_finite()),
        WaveOut::Step { logits, .. } => logits.iter().any(|x| !x.is_finite()),
        WaveOut::StepBatch { logits, .. } => {
            logits.iter().flatten().any(|x| !x.is_finite())
        }
        // A non-final slice has no logits; its K/V rows (and hidden rows)
        // feed the cache, so screen all of them — a poisoned row must
        // fail this attempt, not lurk in the cache.
        WaveOut::Slice { outs, logits, .. } => {
            logits.as_ref().is_some_and(|l| l.iter().any(|x| !x.is_finite()))
                || outs.iter().any(|t| t.to_vec_f32().iter().any(|x| !x.is_finite()))
        }
    }
}

/// Default of [`EngineConfig::batch_decode`]: the `AUTOCHUNK_BATCH_DECODE`
/// env flag (same latching idiom as [`crate::plan::arena_default`], so
/// one process serves one consistent answer). Batched decode is the
/// default since the chunked-prefill PR — set `=0` to opt back into the
/// looped path (still the parity anchor; a CI matrix axis runs both).
pub fn batch_decode_default() -> bool {
    static FLAG: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("AUTOCHUNK_BATCH_DECODE").as_deref() != Ok("0"))
}

/// Default of [`EngineConfig::prefill_chunk_tokens`]: the
/// `AUTOCHUNK_PREFILL_CHUNK` env knob (tokens per slice; unset, `0`, or
/// unparsable keeps prefills monolithic), latched like
/// [`batch_decode_default`].
pub fn prefill_chunk_default() -> usize {
    static V: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("AUTOCHUNK_PREFILL_CHUNK")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0)
    })
}

/// Default of [`EngineConfig::spill_gbps`]: the `AUTOCHUNK_SPILL_GBPS`
/// env knob (simulated slow-tier bandwidth in GB/s; unset, `0`,
/// non-positive, or unparsable keeps the spill tier off), latched like
/// [`prefill_chunk_default`].
pub fn spill_gbps_default() -> f64 {
    static V: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("AUTOCHUNK_SPILL_GBPS")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|g| *g > 0.0 && g.is_finite())
            .unwrap_or(0.0)
    })
}

/// Has `req`'s deadline expired at `clock`? `deadline_ticks == 0` means
/// no deadline; otherwise expiry is strictly *after*
/// `arrival_tick + deadline_ticks` — the deadline tick itself is still
/// valid (a request completing exactly on its deadline meets its SLO).
/// Saturating so sentinel-large deadlines (`u64::MAX`) mean "never",
/// instead of wrapping into the past and shedding on arrival.
fn deadline_expired(clock: u64, req: &Request) -> bool {
    req.deadline_ticks > 0 && clock > req.arrival_tick.saturating_add(req.deadline_ticks)
}

/// Deterministic exponential backoff for fault retries, in virtual
/// ticks. Ordinals 0 and 1 both map to an immediate retry — the first
/// real retry is ordinal 1 (callers pass `retries + 1`) and transient
/// faults usually clear at once — then the ladder doubles from 1 tick,
/// capped at 64: `0, 0, 1, 2, 4, 8, 16, 32, 64, 64, …`
/// (`backoff_ladder_is_pinned` pins the exact sequence).
fn backoff_ticks(retry: usize) -> u64 {
    if retry <= 1 {
        0
    } else {
        1u64 << (retry - 2).min(6)
    }
}

/// Re-insert a retried/preempted request into the queue respecting the
/// admission order (priority class first, then deadline slack, then
/// arrival). The entry lands at the *head of its class* among
/// already-arrived entries — never ahead of a higher-priority or
/// tighter-deadline arrival, which the old unconditional `push_front`
/// allowed a low-priority deepening retry to do — and never past the
/// arrival horizon: entries with `arrival_tick > clock` stay a strictly
/// arrival-sorted tail, the invariant the admission scan's early break
/// rests on. All-zero priorities with no deadlines reduce to the legacy
/// head insert exactly.
fn requeue(queue: &mut VecDeque<Pending>, requests: &[Request], clock: u64, p: Pending) {
    let class = |q: &Pending| {
        let r = &requests[q.idx];
        let slack = if r.deadline_ticks == 0 {
            u64::MAX
        } else {
            r.arrival_tick.saturating_add(r.deadline_ticks).saturating_sub(clock)
        };
        (Reverse(r.priority), slack)
    };
    let key = class(&p);
    let pos = queue
        .iter()
        .position(|q| requests[q.idx].arrival_tick > clock || class(q) >= key)
        .unwrap_or(queue.len());
    queue.insert(pos, p);
}

#[derive(Clone, Copy)]
enum Mode {
    Continuous,
    Serial,
}

/// Deterministic greedy token selection: strict `>` comparison, lowest
/// index wins ties (NaN never wins). Load-bearing for the bitwise
/// stream-parity contract — the parity tests and benches share this
/// exact rule.
pub fn greedy_argmax(v: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i32
}

/// Zero-pad (or truncate) a token prompt to `len` — the engine's bucket
/// padding rule, shared with the parity tests and benches.
pub fn pad_prompt(tokens: &[i32], len: usize) -> Vec<i32> {
    let mut v = vec![0i32; len];
    let n = tokens.len().min(len);
    v[..n].copy_from_slice(&tokens[..n]);
    v
}

/// The gpt-family config for a bucket, or None for non-generative models.
fn gpt_cfg(model: &str, bucket: usize) -> Option<GptConfig> {
    match model {
        "gpt" => Some(GptConfig { seq: bucket, causal: true, ..Default::default() }),
        "gpt-fused" => Some(GptConfig {
            seq: bucket,
            fused_attention: true,
            causal: true,
            ..Default::default()
        }),
        _ => None,
    }
}

/// Continuous-batching serve engine (native interpreter backend).
pub struct ServeEngine {
    config: EngineConfig,
    cache: HashMap<(PlanKind, usize, usize), PlanHandle>,
    params: HashMap<usize, Vec<Tensor>>,
    /// Unchunked estimated peak per (kind, bucket) (the deepening
    /// ladder's base), computed once rather than once per depth.
    baselines: HashMap<(PlanKind, usize), usize>,
    registry: Registry,
    cache_hits: usize,
    cache_misses: usize,
    /// Trace of the most recent serve call (Some iff tracing was on).
    trace: Option<Trace>,
    /// Compile-lane scope while a serve call is live: `handle()` runs
    /// only on the serial coordinator thread, so one scope sequences
    /// every compile span deterministically.
    trace_compile: Option<TraceScope>,
}

impl ServeEngine {
    pub fn new(mut config: EngineConfig) -> ServeEngine {
        config.buckets.sort_unstable();
        config.buckets.dedup();
        ServeEngine {
            config,
            cache: HashMap::new(),
            params: HashMap::new(),
            baselines: HashMap::new(),
            registry: Registry::in_memory(),
            cache_hits: 0,
            cache_misses: 0,
            trace: None,
            trace_compile: None,
        }
    }

    /// The structured trace recorded by the most recent serve call
    /// (None when tracing was disabled). Taking it resets the slot.
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.take()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Catalog of every variant compiled so far (native tags).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// (hits, misses) of the compiled-plan cache since construction.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.cache_hits, self.cache_misses)
    }

    /// Smallest bucket that holds `seq_len` (None if longer than all).
    pub fn bucket_for(&self, seq_len: usize) -> Option<usize> {
        self.config.buckets.iter().copied().find(|&b| b >= seq_len)
    }

    /// Per-request cost quote at a deepening level: what admission control
    /// would charge a prefill of `seq_len` (compiling and caching the
    /// bucket's plan if needed).
    pub fn quote(&mut self, seq_len: usize, depth: usize) -> Result<Option<(usize, CostQuote)>> {
        let Some(bucket) = self.bucket_for(seq_len) else {
            return Ok(None);
        };
        let h = self.handle(PlanKind::Prefill, bucket, depth)?;
        Ok(Some((bucket, *h.quote())))
    }

    /// Bytes one full-capacity KV cache reserves in `bucket` — the
    /// contiguous backend's admission charge (0 for non-generative
    /// models).
    pub fn kv_bytes(&self, bucket: usize) -> usize {
        gpt_cfg(&self.config.model, bucket).map(|c| c.kv_cache_bytes()).unwrap_or(0)
    }

    /// Admission price of one generative prefill (PrefillKv plan + its
    /// in-wave LM-head call) at depth 0, excluding the cache reservation.
    /// Tests and benches calibrate budgets with this instead of
    /// hard-coding byte counts.
    pub fn gen_cost(&mut self, bucket: usize) -> Result<usize> {
        let h = self.handle(PlanKind::PrefillKv, bucket, 0)?;
        let lm = self.handle(PlanKind::LmHead, bucket, 0)?;
        Ok(Self::admission_cost(self.config.use_arena, &h)
            + Self::admission_cost(self.config.use_arena, &lm))
    }

    /// Admission price of one decode step (decode plan at `past` + LM
    /// head), excluding resident cache bytes and block growth.
    pub fn decode_cost(&mut self, bucket: usize, past: usize) -> Result<usize> {
        let h = self.handle(PlanKind::Decode { past }, bucket, 0)?;
        let lm = self.handle(PlanKind::LmHead, bucket, 0)?;
        Ok(Self::admission_cost(self.config.use_arena, &h)
            + Self::admission_cost(self.config.use_arena, &lm))
    }

    /// Admission price of one *batched* decode wave entry (stacked step
    /// plan at the next-power-of-two width bucket + batched LM head),
    /// excluding resident cache bytes and block growth (DESIGN.md §16).
    /// Tests and benches calibrate batched-mode budgets with this.
    pub fn batched_decode_cost(&mut self, bucket: usize, width: usize) -> Result<usize> {
        let w = width.max(1).next_power_of_two();
        let h = self.handle(PlanKind::DecodeBatched { width: w }, bucket, 0)?;
        let lm = self.handle(PlanKind::LmHeadBatched { width: w }, bucket, 0)?;
        Ok(Self::admission_cost(self.config.use_arena, &h)
            + Self::admission_cost(self.config.use_arena, &lm))
    }

    /// Bytes one KV block pins in paged mode (0 when paged mode is off or
    /// the model is non-generative). Bucket-independent: blocks are
    /// shaped by heads/head_dim/block_tokens only.
    pub fn block_bytes(&self) -> usize {
        if self.config.block_tokens == 0 {
            return 0;
        }
        let probe = self.config.buckets.first().copied().unwrap_or(64);
        gpt_cfg(&self.config.model, probe)
            .map(|c| 2 * c.layers * c.heads * self.config.block_tokens * c.head_dim() * 4)
            .unwrap_or(0)
    }

    /// The bucket's shared weight set (generated once per bucket; every
    /// graph kind is parameter-compatible by construction).
    fn full_params(&mut self, bucket: usize) -> Result<Vec<Tensor>> {
        if let Some(p) = self.params.get(&bucket) {
            return Ok(p.clone());
        }
        let g = build_model(&self.config.model, bucket)?;
        let p = random_params(&g, 0xC0DE + bucket as u64);
        self.params.insert(bucket, p.clone());
        Ok(p)
    }

    fn build_graph(&self, kind: PlanKind, bucket: usize) -> Result<Graph> {
        match kind {
            PlanKind::Prefill => build_model(&self.config.model, bucket),
            _ => {
                let Some(cfg) = gpt_cfg(&self.config.model, bucket) else {
                    crate::bail!(
                        "generation requires a gpt-family model, got '{}'",
                        self.config.model
                    );
                };
                Ok(match kind {
                    PlanKind::PrefillKv => models::gpt_prefill_kv(&cfg),
                    PlanKind::PrefillChunk { past, len } => {
                        models::gpt_prefill_chunk(&cfg, past, len, self.config.block_tokens)
                    }
                    PlanKind::Decode { past } if self.config.block_tokens > 0 => {
                        models::gpt_decode_paged(&cfg, past, self.config.block_tokens)
                    }
                    PlanKind::Decode { past } => models::gpt_decode(&cfg, past),
                    PlanKind::DecodeBatched { width } => {
                        models::gpt_decode_batched(&cfg, width, self.config.block_tokens)
                    }
                    PlanKind::LmHead => models::gpt_lm_head(&cfg),
                    PlanKind::LmHeadBatched { width } => {
                        models::gpt_lm_head_batched(&cfg, width)
                    }
                    PlanKind::Prefill => unreachable!(),
                })
            }
        }
    }

    /// Compile (once) and cache the plan for a (kind, bucket, depth)
    /// triple. Decode steps and the LM head are always dense (their peaks
    /// are O(seq·d) — nothing to chunk).
    fn handle(&mut self, kind: PlanKind, bucket: usize, depth: usize) -> Result<PlanHandle> {
        let key = (kind, bucket, depth);
        if let Some(h) = self.cache.get(&key) {
            self.cache_hits += 1;
            return Ok(h.clone());
        }
        self.cache_misses += 1;
        // `handle()` only runs on the serial coordinator thread, so the
        // compile lane sequences every compile span deterministically.
        let csp = self.trace_compile.as_ref().map(|s| s.begin());
        let graph = self.build_graph(kind, bucket)?;
        let full = self.full_params(bucket)?;
        let params = match kind {
            // weight-tied head: wteᵀ materialized once per bucket
            PlanKind::LmHead | PlanKind::LmHeadBatched { .. } => models::lm_head_params(&full),
            _ => full,
        };
        // Depth ladder relative to the model's own baseline (independent
        // of the budget, so the same cache serves any budget): level 0 is
        // dense, level d targets baseline >> d.
        let chunkable = matches!(
            kind,
            PlanKind::Prefill | PlanKind::PrefillKv | PlanKind::PrefillChunk { .. }
        );
        let mut candidates_seen = 0usize;
        let plans = if depth == 0 || !chunkable {
            Vec::new()
        } else {
            let base_key = (kind, bucket);
            let base = *self
                .baselines
                .entry(base_key)
                .or_insert_with(|| estimate(&graph).peak_bytes);
            let r = autochunk(&graph, (base >> depth).max(1), &self.config.compile);
            candidates_seen = r.candidates_seen;
            r.plans
        };
        let tag = match kind {
            PlanKind::Prefill => format!("{}_native_s{}_d{}", self.config.model, bucket, depth),
            PlanKind::PrefillKv => format!("{}_prefill_s{}_d{}", self.config.model, bucket, depth),
            PlanKind::PrefillChunk { past, len } if self.config.block_tokens > 0 => format!(
                "{}_prefillchunk_s{}_p{}_n{}_blk{}_d{}",
                self.config.model, bucket, past, len, self.config.block_tokens, depth
            ),
            PlanKind::PrefillChunk { past, len } => format!(
                "{}_prefillchunk_s{}_p{}_n{}_d{}",
                self.config.model, bucket, past, len, depth
            ),
            PlanKind::Decode { past } if self.config.block_tokens > 0 => format!(
                "{}_decode_s{}_p{}_blk{}",
                self.config.model, bucket, past, self.config.block_tokens
            ),
            PlanKind::Decode { past } => {
                format!("{}_decode_s{}_p{}", self.config.model, bucket, past)
            }
            PlanKind::DecodeBatched { width } if self.config.block_tokens > 0 => format!(
                "{}_decode_batch{}_s{}_blk{}",
                self.config.model, width, bucket, self.config.block_tokens
            ),
            PlanKind::DecodeBatched { width } => {
                format!("{}_decode_batch{}_s{}", self.config.model, width, bucket)
            }
            PlanKind::LmHead => format!("{}_lmhead_s{}", self.config.model, bucket),
            PlanKind::LmHeadBatched { width } => {
                format!("{}_lmhead_batch{}_s{}", self.config.model, width, bucket)
            }
        };
        // Spill placement (DESIGN.md §18) follows the engine's own knob,
        // not the env default, so one process can compare both modes.
        let spill = if self.config.spill_gbps > 0.0 {
            Some(SpillParams { gbps: self.config.spill_gbps })
        } else {
            None
        };
        let h = PlanHandle::new_with_spill(&tag, graph, plans, params, spill);
        let out_shape = h.graph().node(h.graph().outputs[0]).shape.clone();
        self.registry.register(ArtifactMeta {
            tag: tag.clone(),
            hlo_path: String::new(),
            model: self.config.model.clone(),
            mode: match kind {
                PlanKind::Prefill | PlanKind::PrefillKv | PlanKind::PrefillChunk { .. }
                    if depth > 0 =>
                {
                    "native-chunked"
                }
                PlanKind::Decode { .. } | PlanKind::DecodeBatched { .. } => "native-decode",
                PlanKind::LmHead | PlanKind::LmHeadBatched { .. } => "native-lmhead",
                _ => "native-dense",
            }
            .into(),
            seq: bucket,
            d_model: 0,
            heads: 0,
            layers: 0,
            vocab: 0,
            n_chunks: h.n_chunks_max(),
            num_params: h.graph().params.len(),
            param_names: Vec::new(),
            est_activation_bytes: h.quote().peak_bytes,
            output_shape: out_shape,
        });
        if let (Some(s), Some(sp)) = (&self.trace_compile, csp) {
            s.end(
                sp,
                "compile",
                vec![
                    ("tag", ArgV::S(tag.clone())),
                    ("bucket", ArgV::U(bucket as u64)),
                    ("depth", ArgV::U(depth as u64)),
                    ("candidates", ArgV::U(candidates_seen as u64)),
                    ("n_chunks", ArgV::U(h.n_chunks_max() as u64)),
                ],
            );
        }
        self.cache.insert(key, h.clone());
        Ok(h)
    }

    /// Serve an open-loop workload continuously to completion.
    pub fn serve(&mut self, requests: &[Request]) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let width = match self.config.worker_threads {
            0 => pool::num_threads(),
            n => n,
        };
        pool::with_threads(width, || self.serve_inner(requests, Mode::Continuous))
    }

    /// Legacy back-to-back path: one wave entry at a time, in arrival
    /// order (a generation runs prefill + every decode step before the
    /// next request starts) — the PR-1 `serve()` semantics on the native
    /// backend. Kept as the determinism baseline and the bench's
    /// throughput baseline.
    pub fn serve_serial(
        &mut self,
        requests: &[Request],
    ) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let width = match self.config.worker_threads {
            0 => pool::num_threads(),
            n => n,
        };
        pool::with_threads(width, || self.serve_inner(requests, Mode::Serial))
    }

    /// Admission price of one request under a handle: the memory
    /// planner's exact bound in arena mode (the certified bound for what
    /// the arena executor actually runs — never substituted by the quote,
    /// which can under-model batch-expansion workspace), else the quote.
    /// Persistent (cache) inputs are excluded on both sides — the engine
    /// charges resident KV bytes separately.
    fn admission_cost(use_arena: bool, h: &PlanHandle) -> usize {
        if use_arena {
            h.memplan().admission_bytes(1)
        } else {
            h.quote().peak_bytes
        }
    }

    fn serve_inner(
        &mut self,
        requests: &[Request],
        mode: Mode,
    ) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let t0 = Instant::now();
        let mut recorder = Recorder::new();
        let tracker = MemoryTracker::new();
        let (hits0, miss0) = (self.cache_hits, self.cache_misses);
        let mut responses: Vec<EngineResponse> = Vec::with_capacity(requests.len());

        // Chaos harness (DESIGN.md §15): injected faults surface as
        // panics with a typed payload, caught per wave entry — silence
        // the default hook's backtrace spew for those payloads only.
        let faults = self.config.faults.clone();
        if faults.is_some() {
            silence_injected_panics();
        }
        let mut auditor = if self.config.audit { Some(Auditor::new()) } else { None };
        // Request ids any destructive injected fault touched (any
        // attempt): reported on responses for the soak's bitwise check.
        let mut touched: HashSet<usize> = HashSet::new();

        // Structured trace (DESIGN.md §19), on only when asked — every
        // instrumentation site below is a single `None` branch otherwise.
        // Events attribute to logical lanes (engine/kv/compile/wave slot)
        // with deterministic sequence numbers, never to worker threads,
        // so the same seed records the same trace at any pool width.
        let tr: Option<Trace> = if self.config.trace || trace::trace_path_from_env().is_some() {
            let config = vec![
                ("model".to_string(), self.config.model.clone()),
                ("budget_bytes".to_string(), self.config.budget_bytes.to_string()),
                ("use_arena".to_string(), self.config.use_arena.to_string()),
                ("batch_decode".to_string(), self.config.batch_decode.to_string()),
                ("block_tokens".to_string(), self.config.block_tokens.to_string()),
                (
                    "prefill_chunk_tokens".to_string(),
                    self.config.prefill_chunk_tokens.to_string(),
                ),
                ("spill_gbps".to_string(), self.config.spill_gbps.to_string()),
                ("threads".to_string(), pool::num_threads().to_string()),
            ];
            Some(Trace::new(TraceHeader {
                fault_seed: faults.as_ref().map(|p| p.seed()),
                config,
            }))
        } else {
            None
        };
        let eng = tr.as_ref().map(|t| t.scope(trace::LANE_ENGINE));
        let kv_scope = tr.as_ref().map(|t| t.scope(trace::LANE_KV));
        self.trace_compile = tr.as_ref().map(|t| t.scope(trace::LANE_COMPILE));

        // Paged mode: one block pool + prefix-share index per run, on the
        // run tracker, so resident blocks are part of the measured peak
        // and the drain contract (`final_blocks_in_use == 0`,
        // `measured_final_bytes == 0`) is checked against real storage.
        let mut mgr: Option<CacheManager> = if self.config.block_tokens > 0 {
            let probe = self.config.buckets.first().copied().unwrap_or(64);
            let bb = self.block_bytes();
            gpt_cfg(&self.config.model, probe).map(|cfg| {
                let cap = if self.config.pool_blocks > 0 {
                    self.config.pool_blocks
                } else {
                    // byte admission bounds real use at budget/block; the
                    // clamp only guards absurd budgets (probe engines)
                    (self.config.budget_bytes / bb).clamp(1, 65536)
                };
                CacheManager::new(
                    cfg.layers,
                    cfg.heads,
                    self.config.block_tokens,
                    cfg.head_dim(),
                    cap,
                    Some(tracker.clone()),
                )
            })
        } else {
            None
        };
        if let (Some(m), Some(plan)) = (&mut mgr, &faults) {
            m.set_faults(plan.clone());
        }
        if let (Some(m), Some(ks)) = (&mut mgr, &kv_scope) {
            m.set_trace(ks.clone());
        }
        // Evicted generations waiting to re-prefill: request idx → stream
        // state (entries live from eviction until re-admission/rejection).
        let mut resume: HashMap<usize, ResumeState> = HashMap::new();
        // Chunked prefill (DESIGN.md §17): generative prompts longer than
        // this run as `prefill_chunk_tokens`-row slices interleaved with
        // decode waves. 0 = monolithic (the serial-parity default).
        let chunk = self.config.prefill_chunk_tokens;
        // Requests whose *first* admission already recorded queueing wait:
        // re-admissions (evictions, fault retries, chunked re-prefills)
        // must not re-count, so wait percentiles stay admission-honest.
        let mut waited: HashSet<usize> = HashSet::new();

        // Arrival-ordered queue, higher priority class first within a
        // tick, stable by id (all-zero priorities reduce to the legacy
        // arrival order exactly).
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by_key(|&i| {
            (requests[i].arrival_tick, Reverse(requests[i].priority), requests[i].id)
        });
        let mut queue: VecDeque<Pending> = order
            .into_iter()
            .map(|idx| Pending { idx, depth: 0, evictions: 0, retries: 0, not_before: 0 })
            .collect();

        let max_batch = match mode {
            Mode::Serial => 1,
            Mode::Continuous => self.config.max_batch.max(1),
        };
        let mut clock: u64 = 0;
        let mut gens: Vec<GenState> = Vec::new();
        let mut stalled_rounds = 0usize;

        while !queue.is_empty() || !gens.is_empty() {
            // Fast-forward the virtual clock to the next runnable tick
            // (arrival or backoff expiry) when no decode work is pending.
            if gens.is_empty() {
                let next = queue
                    .iter()
                    .map(|p| requests[p.idx].arrival_tick.max(p.not_before))
                    .min();
                if let Some(next) = next {
                    if next > clock {
                        clock = next;
                    }
                }
            }

            // Deadline sweep: a generation whose deadline expired is
            // load-shed now — its cache frees before this wave's
            // admission prices residency. Checked between decode steps,
            // so a missed deadline never wedges the budget.
            let mut di = 0;
            while di < gens.len() {
                let req = &requests[gens[di].idx];
                if deadline_expired(clock, req) {
                    let g = gens.remove(di);
                    match g.cache {
                        GenCache::Paged(tb) => match &mut mgr {
                            Some(m) => m.release_table(tb),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Spilled(st) => match &mgr {
                            Some(m) => m.discard_spilled(st),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Whole(_) => {}
                    }
                    recorder.deadline_missed += 1;
                    recorder.rejected += 1;
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "shed",
                        "deadline_missed",
                        g.bucket,
                        g.depth,
                        0,
                        0,
                        self.config.budget_bytes,
                        0,
                        0,
                    );
                    responses.push(EngineResponse::rejected(
                        req.id,
                        g.depth,
                        RejectReason::DeadlineMissed,
                        clock,
                    ));
                } else {
                    di += 1;
                }
            }

            // Queue deadline sweep (the backoff-queue shedding bugfix):
            // the whole queue, every tick — a request parked behind a
            // full batch or a backoff window is shed the tick its
            // deadline expires, not whenever it next reaches admission.
            // (The admission scan breaks at the arrival horizon and skips
            // backoff entries entirely, so it cannot be the shed point.)
            let mut qi = 0;
            while qi < queue.len() {
                let p = queue[qi];
                let req = &requests[p.idx];
                if deadline_expired(clock, req) {
                    queue.remove(qi);
                    resume.remove(&p.idx);
                    recorder.deadline_missed += 1;
                    recorder.rejected += 1;
                    recorder.shed_wait += 1;
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "shed",
                        "deadline_missed",
                        0,
                        p.depth,
                        0,
                        0,
                        self.config.budget_bytes,
                        0,
                        0,
                    );
                    responses.push(EngineResponse::rejected(
                        req.id,
                        p.depth,
                        RejectReason::DeadlineMissed,
                        clock,
                    ));
                } else {
                    qi += 1;
                }
            }

            // Live caches hold their bytes whether or not they execute
            // this wave: admission packs the *remaining* budget. Under
            // the paged pool residency is blocks-in-use (shared prefix
            // blocks count once); the contiguous backend truly pins full
            // capacity per cache.
            let resident: usize = match &mgr {
                Some(m) => m.resident_bytes(),
                None => gens
                    .iter()
                    .map(|g| match &g.cache {
                        GenCache::Whole(c) => c.capacity_bytes(),
                        GenCache::Paged(_) => 0,
                        GenCache::Spilled(_) => 0,
                    })
                    .sum(),
            };
            let mut remaining = self.config.budget_bytes.saturating_sub(resident);
            // Paged mode: blocks this wave may still allocate (seeds,
            // boundary appends, copy-on-writes) — a wave-local ledger
            // against the pool's free list, conservative about sharing.
            let mut free_blocks_wave = mgr.as_ref().map(|m| m.free_blocks()).unwrap_or(0);
            // Restore pre-pass: revive spilled KV tables while the pool has
            // room. A restore needs one block of headroom past the table
            // itself (`want`) so the revived decode can append — gating on
            // the bare block count would restore into a full pool and
            // immediately re-stall, ping-ponging spill/restore until the
            // eviction counter wedges the stream.
            if mgr.is_some() {
                for gi in 0..gens.len() {
                    let need = match &gens[gi].cache {
                        GenCache::Spilled(st) => st.n_blocks(),
                        _ => continue,
                    };
                    let m = mgr.as_mut().expect("spilled cache implies paged mode");
                    let bytes = need * m.block_bytes();
                    let want = (need + 1).min(m.pool_blocks());
                    if want > free_blocks_wave || bytes > remaining {
                        continue;
                    }
                    let restored = match &gens[gi].cache {
                        GenCache::Spilled(st) => m.restore_table(st),
                        _ => unreachable!(),
                    };
                    match restored {
                        Ok(tb) => {
                            explain_admission(
                                &eng,
                                clock,
                                requests[gens[gi].idx].id,
                                "restore",
                                "spill_restore",
                                gens[gi].bucket,
                                gens[gi].depth,
                                bytes,
                                remaining,
                                self.config.budget_bytes,
                                need,
                                free_blocks_wave,
                            );
                            remaining -= bytes;
                            free_blocks_wave -= need;
                            recorder.kv_restores += 1;
                            recorder.kv_restore_bytes += bytes;
                            gens[gi].latency_us = gens[gi].latency_us.saturating_add(
                                placement_cost_us(bytes, 0, self.config.spill_gbps) as u64,
                            );
                            gens[gi].cache = GenCache::Paged(tb);
                        }
                        Err(e) => {
                            recorder.record_error(e.kind());
                        }
                    }
                }
            }
            let mut wave: Vec<WaveEntry> = Vec::new();
            // Admitted *requests* this wave (a batched decode entry holds
            // several) — what `max_batch` bounds. Looped mode admits one
            // request per entry, so `slots == wave.len()` there and this
            // refactor changes nothing.
            let mut slots = 0usize;

            // ---- decode admission: one step per active generation, in
            // admission order (decode-first keeps caches short-lived,
            // freeing resident bytes fastest).
            if self.config.batch_decode {
                // Batched decode (DESIGN.md §16): group active
                // generations by sequence bucket, in `gens` order, and
                // admit each group as ONE fused wave entry. The plan is
                // keyed by (bucket, width-rounded-to-power-of-two), so
                // warm waves reuse compiled plans and arenas; a group
                // that does not fit sheds members from the end until it
                // does (the survivors decode this wave; the rest wait —
                // token streams are schedule-independent, so admission
                // order never shows in the bits).
                let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
                for gi in 0..gens.len() {
                    if gens[gi].tokens.is_empty() {
                        continue; // mid-prefill: no input token to decode yet
                    }
                    if matches!(gens[gi].cache, GenCache::Spilled(_)) {
                        continue; // parked in the slow tier: waits for the restore pre-pass
                    }
                    let b = gens[gi].bucket;
                    match groups.iter_mut().find(|(gb, _)| *gb == b) {
                        Some((_, v)) => v.push(gi),
                        None => groups.push((b, vec![gi])),
                    }
                }
                for (bucket, mut gis) in groups {
                    if slots >= max_batch {
                        break;
                    }
                    gis.truncate(max_batch - slots);
                    while !gis.is_empty() {
                        let width = gis.len().next_power_of_two();
                        let h = self.handle(PlanKind::DecodeBatched { width }, bucket, 0)?;
                        let lm = self.handle(PlanKind::LmHeadBatched { width }, bucket, 0)?;
                        // One batched step is priced exactly like the
                        // looped entries it replaces: the plan's exact
                        // planned peak (or quote) + the head, plus every
                        // member's block growth. (The looped path's
                        // per-`past` persistent-bytes identity does not
                        // apply here — the batched graph binds padded
                        // full-bucket slot counts so one plan serves any
                        // `past` mix; its persistent inputs are excluded
                        // from admission_cost either way.)
                        let mut cost = Self::admission_cost(self.config.use_arena, &h)
                            + Self::admission_cost(self.config.use_arena, &lm);
                        let mut need_blocks = 0usize;
                        if let Some(m) = &mgr {
                            for &gi in &gis {
                                if let GenCache::Paged(tb) = &gens[gi].cache {
                                    if m.append_needs_block(tb) {
                                        need_blocks += 1;
                                    }
                                }
                            }
                            cost += need_blocks * m.block_bytes();
                        }
                        if cost <= remaining && need_blocks <= free_blocks_wave {
                            for &gi in &gis {
                                explain_admission(
                                    &eng,
                                    clock,
                                    requests[gens[gi].idx].id,
                                    "admit",
                                    "decode_batched",
                                    bucket,
                                    gens[gi].depth,
                                    cost,
                                    remaining,
                                    self.config.budget_bytes,
                                    need_blocks,
                                    free_blocks_wave,
                                );
                            }
                            remaining -= cost;
                            free_blocks_wave -= need_blocks;
                            slots += gis.len();
                            wave.push(WaveEntry::DecodeBatched { gis, h, lm, width });
                            break;
                        }
                        if let Some(gi) = gis.pop() {
                            explain_admission(
                                &eng,
                                clock,
                                requests[gens[gi].idx].id,
                                "defer",
                                "wave_budget",
                                bucket,
                                gens[gi].depth,
                                cost,
                                remaining,
                                self.config.budget_bytes,
                                need_blocks,
                                free_blocks_wave,
                            );
                        }
                    }
                }
            } else {
                for gi in 0..gens.len() {
                    if slots >= max_batch {
                        break;
                    }
                    if gens[gi].tokens.is_empty() {
                        continue; // mid-prefill: no input token to decode yet
                    }
                    if matches!(gens[gi].cache, GenCache::Spilled(_)) {
                        continue; // parked in the slow tier: waits for the restore pre-pass
                    }
                    let (bucket, past) = (gens[gi].bucket, gens[gi].past);
                    let h = self.handle(PlanKind::Decode { past }, bucket, 0)?;
                    let lm = self.handle(PlanKind::LmHead, bucket, 0)?;
                    // the step price covers token selection too: the LM head
                    // runs inside the same wave entry
                    let mut cost = Self::admission_cost(self.config.use_arena, &h)
                        + Self::admission_cost(self.config.use_arena, &lm);
                    // Grow-as-you-go: a step that crosses a block boundary
                    // (or must copy-on-write a shared tail block) buys its
                    // block now, at block — not bucket — granularity.
                    let mut need_blocks = 0usize;
                    if let (Some(m), GenCache::Paged(tb)) = (&mgr, &gens[gi].cache) {
                        debug_assert_eq!(
                            h.quote().persistent_bytes,
                            m.blocks_for(past) * m.block_bytes(),
                            "decode graph must price resident state at block granularity"
                        );
                        if m.append_needs_block(tb) {
                            need_blocks = 1;
                        }
                        cost += need_blocks * m.block_bytes();
                    }
                    if cost <= remaining && need_blocks <= free_blocks_wave {
                        explain_admission(
                            &eng,
                            clock,
                            requests[gens[gi].idx].id,
                            "admit",
                            "decode",
                            bucket,
                            gens[gi].depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        remaining -= cost;
                        free_blocks_wave -= need_blocks;
                        slots += 1;
                        wave.push(WaveEntry::Decode { gi, h, lm });
                    } else {
                        explain_admission(
                            &eng,
                            clock,
                            requests[gens[gi].idx].id,
                            "defer",
                            "wave_budget",
                            bucket,
                            gens[gi].depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                    }
                }
            }

            // ---- chunked-prefill slice admission: one slice per
            // in-progress prefill per wave, after decode (decode-first is
            // what bounds ITL under long prompts — the Sarathi insight).
            // Order: priority class first, then tightest deadline slack,
            // then arrival — so an urgent prefill drains ahead of a lazy
            // one. A slice that doesn't fit simply pauses: the generation
            // keeps its cache (blocks stay resident and priced) and the
            // next wave retries from the exact same `past`; the
            // stall-eviction backstop spills it if residency wedges the
            // budget.
            if chunk > 0 {
                let mut cands: Vec<usize> = (0..gens.len())
                    .filter(|&gi| {
                        gens[gi].past < gens[gi].plen
                            && !matches!(gens[gi].cache, GenCache::Spilled(_))
                    })
                    .collect();
                cands.sort_by_key(|&gi| {
                    let req = &requests[gens[gi].idx];
                    let slack = if req.deadline_ticks == 0 {
                        u64::MAX
                    } else {
                        req.arrival_tick
                            .saturating_add(req.deadline_ticks)
                            .saturating_sub(clock)
                    };
                    (Reverse(req.priority), slack, req.arrival_tick, req.id)
                });
                for gi in cands {
                    if slots >= max_batch {
                        break;
                    }
                    let (bucket, past, plen, depth) = {
                        let g = &gens[gi];
                        (g.bucket, g.past, g.plen, g.depth)
                    };
                    let n = chunk.min(plen - past);
                    let h = self.handle(PlanKind::PrefillChunk { past, len: n }, bucket, depth)?;
                    // the final slice selects the first token in-wave
                    let lm = if past + n == plen {
                        Some(self.handle(PlanKind::LmHead, bucket, 0)?)
                    } else {
                        None
                    };
                    let mut cost = Self::admission_cost(self.config.use_arena, &h);
                    if let Some(lm) = &lm {
                        cost += Self::admission_cost(self.config.use_arena, lm);
                    }
                    // Grow-as-you-go at slice granularity: only the blocks
                    // this slice's rows spill past the held tail. (Slice
                    // tables are never prefix-shared, so no CoW cost.)
                    let mut need_blocks = 0usize;
                    if let (Some(m), GenCache::Paged(tb)) = (&mgr, &gens[gi].cache) {
                        need_blocks = m.blocks_for(past + n).saturating_sub(tb.blocks().len());
                        cost += need_blocks * m.block_bytes();
                    }
                    if cost <= remaining && need_blocks <= free_blocks_wave {
                        explain_admission(
                            &eng,
                            clock,
                            requests[gens[gi].idx].id,
                            "admit",
                            "prefill_slice",
                            bucket,
                            depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        remaining -= cost;
                        free_blocks_wave -= need_blocks;
                        slots += 1;
                        wave.push(WaveEntry::PrefillSlice { gi, n, h, lm });
                    } else {
                        explain_admission(
                            &eng,
                            clock,
                            requests[gens[gi].idx].id,
                            "defer",
                            "wave_budget",
                            bucket,
                            depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                    }
                }
            }

            // ---- prefill admission: pack the rest of the wave
            let mut retry: Vec<Pending> = Vec::new();
            let mut scan = 0usize;
            while scan < queue.len() && slots < max_batch {
                if requests[queue[scan].idx].arrival_tick > clock {
                    break; // queue is arrival-sorted: nothing further has arrived
                }
                let p = queue[scan];
                let req = &requests[p.idx];
                // (Expired deadlines were already shed by this tick's
                // queue sweep — nothing scanned here can be past due.)
                debug_assert!(!deadline_expired(clock, req));
                // Backing off after a fault retry: arrived but not yet
                // runnable — skip, keep scanning.
                if p.not_before > clock {
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "backoff",
                        "fault_retry",
                        0,
                        p.depth,
                        0,
                        remaining,
                        self.config.budget_bytes,
                        0,
                        free_blocks_wave,
                    );
                    scan += 1;
                    continue;
                }
                let generative = req.max_new_tokens > 0;
                // Generation routes by total footprint: the cache —
                // contiguous or paged — must hold the prompt plus every
                // generated position.
                let Some(bucket) = self.bucket_for(req.total_len()) else {
                    queue.remove(scan);
                    resume.remove(&p.idx);
                    recorder.rejected += 1;
                    recorder.shed_wait += 1;
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "shed",
                        "too_long",
                        0,
                        p.depth,
                        0,
                        remaining,
                        self.config.budget_bytes,
                        0,
                        free_blocks_wave,
                    );
                    responses.push(EngineResponse::rejected(
                        req.id,
                        p.depth,
                        RejectReason::TooLong,
                        clock,
                    ));
                    continue;
                };
                if generative && (gpt_cfg(&self.config.model, bucket).is_none() || req.seq_len == 0)
                {
                    // generation is only defined for the gpt family, and
                    // needs at least one prompt token to seed the cache
                    queue.remove(scan);
                    resume.remove(&p.idx);
                    recorder.rejected += 1;
                    recorder.shed_wait += 1;
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "shed",
                        "not_generable",
                        bucket,
                        p.depth,
                        0,
                        remaining,
                        self.config.budget_bytes,
                        0,
                        free_blocks_wave,
                    );
                    responses.push(EngineResponse::rejected(
                        req.id,
                        p.depth,
                        RejectReason::NotGenerable,
                        clock,
                    ));
                    continue;
                }
                // Chunked admission: a long generative prompt enters the
                // engine as a *generation still prefilling* — a GenState
                // with `past < plen` — and streams in `chunk`-row slices
                // interleaved with decode waves. Short prompts (≤ chunk)
                // keep the monolithic path, whose single fused prefill is
                // strictly cheaper.
                let plen_eff = if generative {
                    req.seq_len + resume.get(&p.idx).map(|r| r.tokens.len() - 1).unwrap_or(0)
                } else {
                    req.seq_len
                };
                if generative && chunk > 0 && plen_eff > chunk {
                    let h =
                        self.handle(PlanKind::PrefillChunk { past: 0, len: chunk }, bucket, p.depth)?;
                    // The irreducible floor is the cache reservation the
                    // generation pins for its whole life. Contiguous:
                    // full bucket capacity up front. Paged: the first
                    // slice's blocks now — later slices and decode steps
                    // grow per wave, backstopped by eviction.
                    let mut extra = 0usize;
                    let mut need_blocks = 0usize;
                    match &mgr {
                        Some(m) => {
                            need_blocks = m.blocks_for(chunk);
                            extra += need_blocks * m.block_bytes();
                            if m.blocks_for(req.total_len()) > m.pool_blocks() {
                                queue.remove(scan);
                                resume.remove(&p.idx);
                                recorder.shed += 1;
                                recorder.rejected += 1;
                                recorder.shed_wait += 1;
                                explain_admission(
                                    &eng,
                                    clock,
                                    req.id,
                                    "shed",
                                    "pool_too_small",
                                    bucket,
                                    p.depth,
                                    0,
                                    remaining,
                                    self.config.budget_bytes,
                                    m.blocks_for(req.total_len()),
                                    m.pool_blocks(),
                                );
                                responses.push(EngineResponse::rejected(
                                    req.id,
                                    p.depth,
                                    RejectReason::PoolTooSmall,
                                    clock,
                                ));
                                continue;
                            }
                        }
                        None => extra += self.kv_bytes(bucket),
                    }
                    if extra >= self.config.budget_bytes {
                        queue.remove(scan);
                        resume.remove(&p.idx);
                        recorder.rejected += 1;
                        recorder.shed_wait += 1;
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "shed",
                            "budget_floor",
                            bucket,
                            p.depth,
                            extra,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        responses.push(EngineResponse::rejected(
                            req.id,
                            p.depth,
                            RejectReason::BudgetFloor,
                            clock,
                        ));
                        continue;
                    }
                    let cost = Self::admission_cost(self.config.use_arena, &h) + extra;
                    if cost > self.config.budget_bytes {
                        queue.remove(scan);
                        if p.depth < self.config.max_deepen {
                            recorder.preempted += 1;
                            explain_admission(
                                &eng,
                                clock,
                                req.id,
                                "deepen",
                                "memory_wall",
                                bucket,
                                p.depth,
                                cost,
                                remaining,
                                self.config.budget_bytes,
                                need_blocks,
                                free_blocks_wave,
                            );
                            retry.push(Pending {
                                idx: p.idx,
                                depth: p.depth + 1,
                                evictions: p.evictions,
                                retries: p.retries,
                                not_before: 0,
                            });
                        } else {
                            resume.remove(&p.idx);
                            recorder.rejected += 1;
                            recorder.shed_wait += 1;
                            explain_admission(
                                &eng,
                                clock,
                                req.id,
                                "shed",
                                "memory_wall",
                                bucket,
                                p.depth,
                                cost,
                                remaining,
                                self.config.budget_bytes,
                                need_blocks,
                                free_blocks_wave,
                            );
                            responses.push(EngineResponse::rejected(
                                req.id,
                                p.depth,
                                RejectReason::MemoryWall,
                                clock,
                            ));
                        }
                        continue;
                    }
                    if cost <= remaining && need_blocks <= free_blocks_wave {
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "admit",
                            "prefill_chunked",
                            bucket,
                            p.depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        remaining -= cost;
                        free_blocks_wave -= need_blocks;
                        queue.remove(scan);
                        let pending_resume = resume.remove(&p.idx);
                        let mut ptoks = req.tokens.clone();
                        if let Some(r) = &pending_resume {
                            // re-prefill over prompt ++ generated-but-last
                            ptoks.extend_from_slice(&r.tokens[..r.tokens.len() - 1]);
                        }
                        let wait_ticks = clock - req.arrival_tick;
                        if waited.insert(p.idx) {
                            recorder.record_wait(wait_ticks * self.config.tick_us);
                        }
                        let cache = match &mgr {
                            Some(_) => GenCache::Paged(BlockTable::new()),
                            None => {
                                let Some(cfg) = gpt_cfg(&self.config.model, bucket) else {
                                    return Err(EngineError::NonGptGeneration.into());
                                };
                                GenCache::Whole(KvCache::new(
                                    cfg.layers,
                                    cfg.heads,
                                    bucket,
                                    cfg.head_dim(),
                                    Some(tracker.clone()),
                                ))
                            }
                        };
                        gens.push(GenState {
                            idx: p.idx,
                            bucket,
                            depth: p.depth,
                            plan_tag: h.tag().to_string(),
                            cache,
                            tokens: Vec::new(),
                            past: 0,
                            plen: plen_eff,
                            ptoks,
                            pending_resume,
                            last_logits: Vec::new(),
                            wait_ticks,
                            latency_us: 0,
                            decode_steps: 0,
                            last_emit: None,
                            evictions: p.evictions,
                            retries: p.retries,
                        });
                        slots += 1;
                        wave.push(WaveEntry::PrefillSlice {
                            gi: gens.len() - 1,
                            n: chunk,
                            h,
                            lm: None,
                        });
                        continue;
                    }
                    // Fits the device but not this wave: skip-ahead.
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "defer",
                        "wave_budget",
                        bucket,
                        p.depth,
                        cost,
                        remaining,
                        self.config.budget_bytes,
                        need_blocks,
                        free_blocks_wave,
                    );
                    scan += 1;
                    continue;
                }
                let kind = if generative { PlanKind::PrefillKv } else { PlanKind::Prefill };
                let h = self.handle(kind, bucket, p.depth)?;
                // Every generative prefill pays for its in-wave LM-head
                // call plus its cache reservation. Contiguous backend:
                // full bucket capacity up front, so seeding can never
                // overshoot. Paged backend: only the blocks the (possibly
                // resumed) prompt seeds — grow-as-you-go; later growth is
                // priced per decode step and backstopped by eviction.
                let mut extra = 0usize;
                let mut need_blocks = 0usize;
                if generative {
                    let lm = self.handle(PlanKind::LmHead, bucket, 0)?;
                    extra += Self::admission_cost(self.config.use_arena, &lm);
                    match &mgr {
                        Some(m) => {
                            let plen_eff = req.seq_len
                                + resume.get(&p.idx).map(|r| r.tokens.len() - 1).unwrap_or(0);
                            need_blocks = m.blocks_for(plen_eff);
                            extra += need_blocks * m.block_bytes();
                            if m.blocks_for(req.total_len()) > m.pool_blocks() {
                                // The pool can never hold this request,
                                // even running alone: shed now instead of
                                // an admit-evict thrash that would end in
                                // the same rejection after max_evictions
                                // recomputes (this check dominates the
                                // old prompt-only one — total_len covers
                                // every position the cache must reach).
                                queue.remove(scan);
                                resume.remove(&p.idx);
                                recorder.shed += 1;
                                recorder.rejected += 1;
                                recorder.shed_wait += 1;
                                explain_admission(
                                    &eng,
                                    clock,
                                    req.id,
                                    "shed",
                                    "pool_too_small",
                                    bucket,
                                    p.depth,
                                    0,
                                    remaining,
                                    self.config.budget_bytes,
                                    m.blocks_for(req.total_len()),
                                    m.pool_blocks(),
                                );
                                responses.push(EngineResponse::rejected(
                                    req.id,
                                    p.depth,
                                    RejectReason::PoolTooSmall,
                                    clock,
                                ));
                                continue;
                            }
                        }
                        None => {
                            if req.max_new_tokens > 1 {
                                extra += self.kv_bytes(bucket);
                            }
                        }
                    }
                }
                if extra >= self.config.budget_bytes {
                    // The irreducible floor (cache + LM head) already
                    // exceeds the budget: no chunk depth can help — reject
                    // now instead of burning max_deepen recompiles.
                    queue.remove(scan);
                    resume.remove(&p.idx);
                    recorder.rejected += 1;
                    recorder.shed_wait += 1;
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "shed",
                        "budget_floor",
                        bucket,
                        p.depth,
                        extra,
                        remaining,
                        self.config.budget_bytes,
                        need_blocks,
                        free_blocks_wave,
                    );
                    responses.push(EngineResponse::rejected(
                        req.id,
                        p.depth,
                        RejectReason::BudgetFloor,
                        clock,
                    ));
                    continue;
                }
                let cost = Self::admission_cost(self.config.use_arena, &h) + extra;
                if cost > self.config.budget_bytes {
                    // Oversized for the device at this depth.
                    queue.remove(scan);
                    if p.depth < self.config.max_deepen {
                        // Preempt to a deeper-chunked retry, not rejection
                        // (a pending resume entry rides along untouched).
                        // Deepening is not a fault retry: no backoff.
                        recorder.preempted += 1;
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "deepen",
                            "memory_wall",
                            bucket,
                            p.depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        retry.push(Pending {
                            idx: p.idx,
                            depth: p.depth + 1,
                            evictions: p.evictions,
                            retries: p.retries,
                            not_before: 0,
                        });
                    } else {
                        resume.remove(&p.idx);
                        recorder.rejected += 1;
                        recorder.shed_wait += 1;
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "shed",
                            "memory_wall",
                            bucket,
                            p.depth,
                            cost,
                            remaining,
                            self.config.budget_bytes,
                            need_blocks,
                            free_blocks_wave,
                        );
                        responses.push(EngineResponse::rejected(
                            req.id,
                            p.depth,
                            RejectReason::MemoryWall,
                            clock,
                        ));
                    }
                    continue;
                }
                if cost <= remaining && need_blocks <= free_blocks_wave {
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "admit",
                        "prefill",
                        bucket,
                        p.depth,
                        cost,
                        remaining,
                        self.config.budget_bytes,
                        need_blocks,
                        free_blocks_wave,
                    );
                    remaining -= cost;
                    free_blocks_wave -= need_blocks;
                    queue.remove(scan);
                    let lm = if generative {
                        Some(self.handle(PlanKind::LmHead, bucket, 0)?)
                    } else {
                        None
                    };
                    let resumed = if generative { resume.remove(&p.idx) } else { None };
                    let ptoks: Vec<i32> = if generative {
                        match &resumed {
                            // re-prefill over prompt ++ generated-but-last:
                            // the last token is the next decode input and
                            // was never cached
                            Some(r) => {
                                let mut t = req.tokens.clone();
                                t.extend_from_slice(&r.tokens[..r.tokens.len() - 1]);
                                t
                            }
                            None => req.tokens.clone(),
                        }
                    } else {
                        Vec::new()
                    };
                    slots += 1;
                    wave.push(WaveEntry::Prefill { p, bucket, h, lm, ptoks, resumed });
                    continue;
                }
                // Fits the device but not this wave: leave it and keep
                // scanning for a smaller arrived request (skip-ahead).
                // Head-of-line priority is preserved — the head gets
                // first claim on the full budget every wave — so no
                // request starves.
                explain_admission(
                    &eng,
                    clock,
                    req.id,
                    "defer",
                    "wave_budget",
                    bucket,
                    p.depth,
                    cost,
                    remaining,
                    self.config.budget_bytes,
                    need_blocks,
                    free_blocks_wave,
                );
                scan += 1;
            }
            // Deepened requests retry at the head of their priority class
            // next wave — never ahead of higher-priority (or tighter-
            // deadline) arrivals already queued.
            for p in retry.into_iter().rev() {
                requeue(&mut queue, requests, clock, p);
            }

            if wave.is_empty() {
                // Only retries/rejections/arrival-waits this tick.
                if !gens.is_empty() {
                    // Budget-stalled decode is a livelock (resident caches
                    // block the very steps that would free them): after a
                    // grace round, evict a victim.
                    stalled_rounds += 1;
                    if stalled_rounds > 2 {
                        match &mut mgr {
                            Some(m) => {
                                // With a spill tier configured, park the
                                // newest spillable generation's blocks in
                                // the slow tier instead of discarding them:
                                // the stream keeps its state and resumes
                                // after a priced restore, no re-prefill.
                                // Each spill burns an eviction credit so a
                                // thrashing stream still falls through to
                                // the rejection path below.
                                let victim = if self.config.spill_gbps > 0.0 {
                                    gens.iter().rposition(|g| {
                                        g.evictions < self.config.max_evictions
                                            && matches!(&g.cache,
                                                GenCache::Paged(tb) if !tb.blocks().is_empty())
                                    })
                                } else {
                                    None
                                };
                                if let Some(vi) = victim {
                                    let taken = std::mem::replace(
                                        &mut gens[vi].cache,
                                        GenCache::Spilled(SpilledTable::default()),
                                    );
                                    let GenCache::Paged(tb) = taken else {
                                        return Err(EngineError::WaveMismatch.into());
                                    };
                                    let st = m.spill_table(tb);
                                    let bytes = st.n_blocks() * m.block_bytes();
                                    recorder.kv_spills += 1;
                                    recorder.kv_spill_bytes += bytes;
                                    explain_admission(
                                        &eng,
                                        clock,
                                        requests[gens[vi].idx].id,
                                        "spill",
                                        "stall",
                                        gens[vi].bucket,
                                        gens[vi].depth,
                                        bytes,
                                        remaining,
                                        self.config.budget_bytes,
                                        st.n_blocks(),
                                        free_blocks_wave,
                                    );
                                    gens[vi].latency_us = gens[vi].latency_us.saturating_add(
                                        placement_cost_us(bytes, 0, self.config.spill_gbps)
                                            as u64,
                                    );
                                    gens[vi].evictions += 1;
                                    gens[vi].cache = GenCache::Spilled(st);
                                    stalled_rounds = 0;
                                    clock += 1;
                                    continue;
                                }
                                // Paged: drop the newest generation's
                                // blocks (least work lost) and re-queue it
                                // for re-prefill recompute — decode parity
                                // makes the recomputed stream bitwise
                                // identical, so eviction trades memory for
                                // FLOPs, not for answers. Only a request
                                // that keeps thrashing is rejected.
                                let Some(g) = gens.pop() else {
                                    return Err(EngineError::StallWithoutGeneration.into());
                                };
                                match g.cache {
                                    GenCache::Paged(tb) => m.release_table(tb),
                                    GenCache::Spilled(st) => m.discard_spilled(st),
                                    GenCache::Whole(_) => {}
                                }
                                if g.evictions >= self.config.max_evictions {
                                    recorder.shed += 1;
                                    recorder.rejected += 1;
                                    explain_admission(
                                        &eng,
                                        clock,
                                        requests[g.idx].id,
                                        "shed",
                                        "eviction_limit",
                                        g.bucket,
                                        g.depth,
                                        0,
                                        remaining,
                                        self.config.budget_bytes,
                                        0,
                                        free_blocks_wave,
                                    );
                                    responses.push(EngineResponse::rejected(
                                        requests[g.idx].id,
                                        g.depth,
                                        RejectReason::EvictionLimit,
                                        clock,
                                    ));
                                } else {
                                    recorder.evicted += 1;
                                    explain_admission(
                                        &eng,
                                        clock,
                                        requests[g.idx].id,
                                        "evict",
                                        "stall",
                                        g.bucket,
                                        g.depth,
                                        0,
                                        remaining,
                                        self.config.budget_bytes,
                                        0,
                                        free_blocks_wave,
                                    );
                                    if g.tokens.is_empty() {
                                        // Evicted mid-prefill: no stream
                                        // state of its own yet — restore
                                        // the resume payload (if any) it
                                        // was admitted with, untouched.
                                        if let Some(r) = g.pending_resume {
                                            resume.insert(g.idx, r);
                                        }
                                    } else {
                                        resume.insert(
                                            g.idx,
                                            ResumeState {
                                                tokens: g.tokens,
                                                decode_steps: g.decode_steps,
                                                last_emit: g.last_emit,
                                            },
                                        );
                                    }
                                    requeue(
                                        &mut queue,
                                        requests,
                                        clock,
                                        Pending {
                                            idx: g.idx,
                                            depth: g.depth,
                                            evictions: g.evictions + 1,
                                            retries: g.retries,
                                            not_before: 0,
                                        },
                                    );
                                }
                            }
                            None => {
                                // Contiguous legacy policy: reject the head.
                                let g = gens.remove(0);
                                recorder.shed += 1;
                                recorder.rejected += 1;
                                explain_admission(
                                    &eng,
                                    clock,
                                    requests[g.idx].id,
                                    "shed",
                                    "eviction_limit",
                                    g.bucket,
                                    g.depth,
                                    0,
                                    remaining,
                                    self.config.budget_bytes,
                                    0,
                                    free_blocks_wave,
                                );
                                responses.push(EngineResponse::rejected(
                                    requests[g.idx].id,
                                    g.depth,
                                    RejectReason::EvictionLimit,
                                    clock,
                                ));
                            }
                        }
                        stalled_rounds = 0;
                    }
                }
                clock += 1;
                continue;
            }
            stalled_rounds = 0;

            // ---- execute the wave: co-resident entries run concurrently
            // on the pool. Leftover headroom (budget − resident − Σ
            // admitted costs) is split evenly across entries and handed to
            // each prefill's chunk-concurrency governor, so the wave total
            // stays ≤ budget. Decode steps and the LM head are unchunked —
            // they run without a governor budget (exact serial loop).
            let per_entry_threads = (pool::num_threads() / wave.len()).max(1);
            let share = remaining / wave.len();
            let use_arena = self.config.use_arena;
            let tick_us = self.config.tick_us;
            let block_tokens = self.config.block_tokens;
            let entries = wave;
            // Decode dispatch accounting (DESIGN.md §16): batched mode
            // issues one model dispatch per bucket group per wave —
            // independent of wave width — where looped mode issues one
            // per request. The bench sweep pins this scaling.
            let decode_entries = entries
                .iter()
                .filter(|e| {
                    matches!(e, WaveEntry::Decode { .. } | WaveEntry::DecodeBatched { .. })
                })
                .count();
            if decode_entries > 0 {
                recorder.decode_waves += 1;
                recorder.decode_dispatches += decode_entries;
                recorder.batched_decode_groups += entries
                    .iter()
                    .filter(|e| matches!(e, WaveEntry::DecodeBatched { .. }))
                    .count();
            }
            // Chunked-prefill accounting: slices issued, and waves where a
            // slice and a decode step genuinely shared the wave — the
            // interleaving the ITL bound rests on (DESIGN.md §17).
            let slice_entries = entries
                .iter()
                .filter(|e| matches!(e, WaveEntry::PrefillSlice { .. }))
                .count();
            recorder.prefill_slices += slice_entries;
            if slice_entries > 0 && decode_entries > 0 {
                recorder.interleaved_waves += 1;
            }
            // Per-bucket dims + shared zero-pad tensor for batched
            // entries, resolved before the parallel section. The pad is
            // engine-owned scratch like the params — untracked — so
            // inert padding rows never inflate the measured peak: one
            // cache-shaped zero tensor (contiguous) or one zero block
            // (paged), cloned into every unbound slot.
            let mut batch_dims: HashMap<usize, (usize, usize, Tensor)> = HashMap::new();
            for e in &entries {
                if let WaveEntry::DecodeBatched { gis, .. } = e {
                    let bucket = gens[gis[0]].bucket;
                    if let std::collections::hash_map::Entry::Vacant(slot) =
                        batch_dims.entry(bucket)
                    {
                        let Some(cfg) = gpt_cfg(&self.config.model, bucket) else {
                            return Err(EngineError::NonGptGeneration.into());
                        };
                        let (nh, dh) = (cfg.heads, cfg.head_dim());
                        let (maxblk, pad) = if block_tokens > 0 {
                            (
                                models::batched_block_slots(bucket, block_tokens),
                                Tensor::from_f32(
                                    vec![0.0; nh * block_tokens * dh],
                                    &[nh, block_tokens, dh],
                                    None,
                                ),
                            )
                        } else {
                            (
                                0,
                                Tensor::from_f32(
                                    vec![0.0; nh * bucket * dh],
                                    &[nh, bucket, dh],
                                    None,
                                ),
                            )
                        };
                        slot.insert((cfg.layers, maxblk, pad));
                    }
                }
            }
            // Request ids per entry (a batched decode entry carries all
            // its members), for attributing fault-touched flags after
            // the entries are consumed.
            let entry_ids: Vec<Vec<usize>> = entries
                .iter()
                .map(|e| match e {
                    WaveEntry::Prefill { p, .. } => vec![requests[p.idx].id],
                    WaveEntry::PrefillSlice { gi, .. } => vec![requests[gens[*gi].idx].id],
                    WaveEntry::Decode { gi, .. } => vec![requests[gens[*gi].idx].id],
                    WaveEntry::DecodeBatched { gis, .. } => {
                        gis.iter().map(|&gi| requests[gens[gi].idx].id).collect()
                    }
                })
                .collect();
            // One fault scope per entry. The key mixes request identity,
            // position in its stream, and the retry ordinal — decisions
            // are pure in (seed, site, key), so the schedule is identical
            // at any pool width, and a retried attempt draws fresh dice.
            let scopes: Vec<Option<FaultScope>> = match &faults {
                Some(plan) => entries
                    .iter()
                    .map(|e| {
                        let key = match e {
                            WaveEntry::Prefill { p, .. } => {
                                ((requests[p.idx].id as u64) << 32)
                                    ^ ((p.depth as u64) << 24)
                                    ^ ((p.evictions as u64) << 16)
                                    ^ ((p.retries as u64) << 4)
                                    ^ 2
                            }
                            WaveEntry::PrefillSlice { gi, .. } => {
                                let g = &gens[*gi];
                                ((requests[g.idx].id as u64) << 32)
                                    ^ ((g.depth as u64) << 24)
                                    ^ ((g.evictions as u64) << 16)
                                    ^ ((g.past as u64) << 8)
                                    ^ ((g.retries as u64) << 4)
                                    ^ 4
                            }
                            WaveEntry::Decode { gi, .. } => {
                                let g = &gens[*gi];
                                ((requests[g.idx].id as u64) << 32)
                                    ^ ((g.past as u64) << 8)
                                    ^ ((g.retries as u64) << 4)
                                    ^ 1
                            }
                            WaveEntry::DecodeBatched { gis, .. } => {
                                // fold every member's identity in, so any
                                // membership change draws fresh dice while
                                // a retried identical group re-rolls via
                                // the members' bumped retry ordinals
                                let mut key = 3u64;
                                for &gi in gis {
                                    let g = &gens[gi];
                                    key ^= ((requests[g.idx].id as u64) << 32)
                                        ^ ((g.past as u64) << 8)
                                        ^ ((g.retries as u64) << 4);
                                    key = key.rotate_left(7);
                                }
                                key
                            }
                        };
                        Some(FaultScope::new(plan.clone(), key))
                    })
                    .collect(),
                None => vec![None; entries.len()],
            };
            // Per-entry trace scopes on wave lanes: events attribute to
            // the entry's *logical* slot (lane 16+wi), never the worker
            // thread that happens to run it, and sequence from a per-wave
            // namespace — so the recorded trace is identical at any pool
            // width (DESIGN.md §19).
            let wave_seq_base = (recorder.waves as u64) << 44;
            let entry_scopes: Vec<Option<TraceScope>> = match &tr {
                Some(t) => (0..entries.len())
                    .map(|wi| Some(t.scope_based(trace::wave_lane(wi), wave_seq_base)))
                    .collect(),
                None => vec![None; entries.len()],
            };
            let wave_span = eng.as_ref().map(|s| s.begin());
            let gens_ro: &Vec<GenState> = &gens;
            let mgr_ro: &Option<CacheManager> = &mgr;
            // Panic isolation: each entry runs under catch_unwind *inside*
            // the pool task (the pool re-raises worker panics), so a
            // poisoned or fault-tripped kernel fails only its own request.
            let results: Vec<Result<WaveOut, EngineError>> =
                pool::parallel_map(entries.len(), |wi| {
                    let fscope = &scopes[wi];
                    let tscope = &entry_scopes[wi];
                    let esp = tscope.as_ref().map(|s| s.begin());
                    let r = catch_unwind(AssertUnwindSafe(|| -> Result<WaveOut, EngineError> {
                        match &entries[wi] {
                            WaveEntry::Prefill { p, h, lm, ptoks, .. } => {
                                let req = &requests[p.idx];
                                pool::with_threads(per_entry_threads, || {
                                    let started = Instant::now();
                                    // generative prefills run over the effective
                                    // prompt (resume extends it with generated
                                    // tokens); plain prefills keep the request's
                                    let ins = match lm {
                                        None => request_inputs(h.graph(), req, &tracker),
                                        Some(_) => prompt_inputs(h.graph(), ptoks, &tracker),
                                    };
                                    let entry_budget = Self::admission_cost(use_arena, h) + share;
                                    let opts = ExecOptions {
                                        budget_bytes: Some(if use_arena {
                                            entry_budget
                                        } else {
                                            h.quote().governor_budget(entry_budget)
                                        }),
                                        use_arena,
                                        faults: fscope.clone(),
                                        trace: tscope.clone(),
                                    };
                                    let (outs, stats) = h.execute(&ins, &tracker, &opts);
                                    drop(ins);
                                    match lm {
                                        None => Ok(WaveOut::Plain {
                                            latency_us: started.elapsed().as_micros() as u64,
                                            out: outs[0].to_vec_f32(),
                                            stats,
                                        }),
                                        Some(lm) => {
                                            // the next token comes off the
                                            // effective prompt's last row
                                            let lm_opts = ExecOptions {
                                                budget_bytes: None,
                                                use_arena,
                                                faults: fscope
                                                    .as_ref()
                                                    .map(|f| f.with_salt(1)),
                                                trace: tscope.clone(),
                                            };
                                            let plen = ptoks.len().max(1);
                                            let hrow = outs[0]
                                                .slice_axis(0, plen - 1, 1)
                                                .to_contiguous(Some(tracker.clone()));
                                            let (louts, _) =
                                                lm.execute(&[hrow], &tracker, &lm_opts);
                                            let logits = louts[0].to_vec_f32();
                                            let token = greedy_argmax(&logits);
                                            Ok(WaveOut::Step {
                                                latency_us: started.elapsed().as_micros() as u64,
                                                outs,
                                                logits,
                                                token,
                                                stats,
                                            })
                                        }
                                    }
                                })
                            }
                            WaveEntry::PrefillSlice { gi, n, h, lm } => {
                                let g = &gens_ro[*gi];
                                let n = *n;
                                pool::with_threads(per_entry_threads, || {
                                    let started = Instant::now();
                                    // slice rows off the effective prompt,
                                    // then the cached prefix (none at the
                                    // first slice — the past-0 graph binds
                                    // no cache inputs)
                                    let mut ins: Vec<Tensor> = Vec::new();
                                    ins.push(Tensor::from_i32(
                                        g.ptoks[g.past..g.past + n].to_vec(),
                                        &[n],
                                        Some(tracker.clone()),
                                    ));
                                    if g.past > 0 {
                                        match &g.cache {
                                            GenCache::Whole(c) => {
                                                for l in 0..c.layers() {
                                                    ins.push(c.k_full(l));
                                                    ins.push(c.v_full(l));
                                                }
                                            }
                                            GenCache::Paged(tb) => match mgr_ro.as_ref() {
                                                Some(m) => m.bind_inputs(tb, &mut ins),
                                                None => {
                                                    return Err(EngineError::MissingManager)
                                                }
                                            },
                                            GenCache::Spilled(_) => {
                                                return Err(EngineError::WaveMismatch)
                                            }
                                        }
                                    }
                                    // slices are chunkable like any other
                                    // prefill: same budget/governor wiring
                                    let entry_budget =
                                        Self::admission_cost(use_arena, h) + share;
                                    let opts = ExecOptions {
                                        budget_bytes: Some(if use_arena {
                                            entry_budget
                                        } else {
                                            h.quote().governor_budget(entry_budget)
                                        }),
                                        use_arena,
                                        faults: fscope.clone(),
                                        trace: tscope.clone(),
                                    };
                                    let (outs, stats) = h.execute(&ins, &tracker, &opts);
                                    drop(ins); // release cache views before the append
                                    let (logits, token) = match lm {
                                        Some(lm) => {
                                            // final slice: the effective
                                            // prompt's last hidden row
                                            // selects the first token
                                            let lm_opts = ExecOptions {
                                                budget_bytes: None,
                                                use_arena,
                                                faults: fscope
                                                    .as_ref()
                                                    .map(|f| f.with_salt(1)),
                                                trace: tscope.clone(),
                                            };
                                            let hrow = outs[0]
                                                .slice_axis(0, n - 1, 1)
                                                .to_contiguous(Some(tracker.clone()));
                                            let (louts, _) =
                                                lm.execute(&[hrow], &tracker, &lm_opts);
                                            let lv = louts[0].to_vec_f32();
                                            let t = greedy_argmax(&lv);
                                            (Some(lv), Some(t))
                                        }
                                        None => (None, None),
                                    };
                                    Ok(WaveOut::Slice {
                                        latency_us: started.elapsed().as_micros() as u64,
                                        outs,
                                        logits,
                                        token,
                                        stats,
                                    })
                                })
                            }
                            WaveEntry::Decode { gi, h, lm } => {
                                let g = &gens_ro[*gi];
                                pool::with_threads(per_entry_threads, || {
                                    let started = Instant::now();
                                    let step_opts = ExecOptions {
                                        budget_bytes: None,
                                        use_arena,
                                        faults: fscope.clone(),
                                        trace: tscope.clone(),
                                    };
                                    let lm_opts = ExecOptions {
                                        budget_bytes: None,
                                        use_arena,
                                        faults: fscope.as_ref().map(|f| f.with_salt(1)),
                                        trace: tscope.clone(),
                                    };
                                    let mut ins: Vec<Tensor> = Vec::new();
                                    ins.push(Tensor::from_i32(
                                        vec![g.next_input_token()],
                                        &[1],
                                        Some(tracker.clone()),
                                    ));
                                    match &g.cache {
                                        GenCache::Whole(c) => {
                                            for l in 0..c.layers() {
                                                ins.push(c.k_full(l));
                                                ins.push(c.v_full(l));
                                            }
                                        }
                                        GenCache::Paged(tb) => match mgr_ro.as_ref() {
                                            Some(m) => m.bind_inputs(tb, &mut ins),
                                            None => return Err(EngineError::MissingManager),
                                        },
                                        GenCache::Spilled(_) => {
                                            return Err(EngineError::WaveMismatch)
                                        }
                                    }
                                    let (outs, stats) = h.execute(&ins, &tracker, &step_opts);
                                    drop(ins); // release cache views before the append
                                    let hrow = outs[0].to_contiguous(Some(tracker.clone()));
                                    let (louts, _) = lm.execute(&[hrow], &tracker, &lm_opts);
                                    let logits = louts[0].to_vec_f32();
                                    let token = greedy_argmax(&logits);
                                    Ok(WaveOut::Step {
                                        latency_us: started.elapsed().as_micros() as u64,
                                        outs,
                                        logits,
                                        token,
                                        stats,
                                    })
                                })
                            }
                            WaveEntry::DecodeBatched { gis, h, lm, width } => {
                                let w = *width;
                                let bucket = gens_ro[gis[0]].bucket;
                                let (layers, maxblk, pad) = batch_dims
                                    .get(&bucket)
                                    .cloned()
                                    .expect("batched entry dims resolved before dispatch");
                                pool::with_threads(per_entry_threads, || {
                                    let started = Instant::now();
                                    let step_opts = ExecOptions {
                                        budget_bytes: None,
                                        use_arena,
                                        faults: fscope.clone(),
                                        trace: tscope.clone(),
                                    };
                                    let lm_opts = ExecOptions {
                                        budget_bytes: None,
                                        use_arena,
                                        faults: fscope.as_ref().map(|f| f.with_salt(1)),
                                        trace: tscope.clone(),
                                    };
                                    // Stacked token/position rows; rows
                                    // beyond the members are inert padding
                                    // (token 0 at position 0 over all-zero
                                    // caches) so a short group reuses the
                                    // width bucket's compiled plan.
                                    let mut toks = vec![0i32; w];
                                    let mut poss = vec![0i32; w];
                                    for (j, &gi) in gis.iter().enumerate() {
                                        let g = &gens_ro[gi];
                                        toks[j] = g.next_input_token();
                                        poss[j] = g.past as i32;
                                    }
                                    let mut ins: Vec<Tensor> = Vec::new();
                                    ins.push(Tensor::from_i32(toks, &[w], Some(tracker.clone())));
                                    ins.push(Tensor::from_i32(poss, &[w], Some(tracker.clone())));
                                    // Cache bindings in the graph's input
                                    // order: per row, per layer — K then V
                                    // (contiguous), or all K block slots
                                    // then all V block slots (paged), held
                                    // blocks first and the shared zero
                                    // block in every slot past them.
                                    for j in 0..w {
                                        if j >= gis.len() {
                                            let per_layer =
                                                if block_tokens > 0 { 2 * maxblk } else { 2 };
                                            for _ in 0..layers * per_layer {
                                                ins.push(pad.clone());
                                            }
                                            continue;
                                        }
                                        match &gens_ro[gis[j]].cache {
                                            GenCache::Whole(c) => {
                                                for l in 0..c.layers() {
                                                    ins.push(c.k_full(l));
                                                    ins.push(c.v_full(l));
                                                }
                                            }
                                            GenCache::Paged(tb) => {
                                                let Some(m) = mgr_ro.as_ref() else {
                                                    return Err(EngineError::MissingManager);
                                                };
                                                let mut tmp: Vec<Tensor> = Vec::new();
                                                m.bind_inputs(tb, &mut tmp);
                                                let nblk = tmp.len() / (2 * layers);
                                                let mut it = tmp.into_iter();
                                                for _ in 0..layers {
                                                    for _ in 0..nblk {
                                                        ins.push(it.next().unwrap());
                                                    }
                                                    for _ in nblk..maxblk {
                                                        ins.push(pad.clone());
                                                    }
                                                    for _ in 0..nblk {
                                                        ins.push(it.next().unwrap());
                                                    }
                                                    for _ in nblk..maxblk {
                                                        ins.push(pad.clone());
                                                    }
                                                }
                                            }
                                            GenCache::Spilled(_) => {
                                                return Err(EngineError::WaveMismatch);
                                            }
                                        }
                                    }
                                    let (outs, stats) = h.execute(&ins, &tracker, &step_opts);
                                    drop(ins); // release cache views before the appends
                                    let hid = outs[0].to_contiguous(Some(tracker.clone()));
                                    let (louts, _) = lm.execute(&[hid], &tracker, &lm_opts);
                                    let mut logits: Vec<Vec<f32>> =
                                        Vec::with_capacity(gis.len());
                                    let mut tokens: Vec<i32> = Vec::with_capacity(gis.len());
                                    for j in 0..gis.len() {
                                        let row = louts[0].slice_axis(0, j, 1).to_vec_f32();
                                        tokens.push(greedy_argmax(&row));
                                        logits.push(row);
                                    }
                                    Ok(WaveOut::StepBatch {
                                        latency_us: started.elapsed().as_micros() as u64,
                                        outs,
                                        logits,
                                        tokens,
                                        stats,
                                    })
                                })
                            }
                        }
                    }))
                    .unwrap_or_else(|payload| Err(EngineError::from_panic(payload)));
                    if let (Some(s), Some(sp)) = (tscope.as_ref(), esp) {
                        let (name, bucket) = match &entries[wi] {
                            WaveEntry::Prefill { bucket, .. } => ("entry.prefill", *bucket),
                            WaveEntry::PrefillSlice { gi, .. } => {
                                ("entry.slice", gens_ro[*gi].bucket)
                            }
                            WaveEntry::Decode { gi, .. } => ("entry.decode", gens_ro[*gi].bucket),
                            WaveEntry::DecodeBatched { gis, .. } => {
                                ("entry.decode_batched", gens_ro[gis[0]].bucket)
                            }
                        };
                        let reqs = entry_ids[wi]
                            .iter()
                            .map(|id| id.to_string())
                            .collect::<Vec<_>>()
                            .join(",");
                        s.end(
                            sp,
                            name,
                            vec![
                                ("bucket", ArgV::U(bucket as u64)),
                                ("reqs", ArgV::S(reqs)),
                                ("ok", ArgV::U(r.is_ok() as u64)),
                            ],
                        );
                    }
                    r
                });
            // Poison screen (chaos runs only): a kernel fault writes NaN
            // into the row downstream consumers read; greedy_argmax never
            // picks a NaN, so without this screen a poisoned step would
            // silently emit token 0 — convert it to a typed failure.
            let results: Vec<Result<WaveOut, EngineError>> = if faults.is_some() {
                results
                    .into_iter()
                    .map(|r| match r {
                        Ok(o) if wave_out_poisoned(&o) => Err(EngineError::KernelPoisoned),
                        other => other,
                    })
                    .collect()
            } else {
                results
            };
            // Fault-touched attribution: the scope's flag is set by any
            // destructive fire during execution (shared across the entry's
            // main and LM-head scopes).
            for (wi, s) in scopes.iter().enumerate() {
                if let Some(fs) = s {
                    if fs.touched() {
                        touched.extend(entry_ids[wi].iter().copied());
                    }
                }
            }
            if let (Some(s), Some(sp)) = (&eng, wave_span) {
                let reqs = entry_ids
                    .iter()
                    .flatten()
                    .map(|id| id.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                s.end(
                    sp,
                    "wave",
                    vec![
                        ("tick", ArgV::U(clock)),
                        ("wave", ArgV::U(recorder.waves as u64)),
                        ("entries", ArgV::U(entry_ids.len() as u64)),
                        ("decode_entries", ArgV::U(decode_entries as u64)),
                        ("slice_entries", ArgV::U(slice_entries as u64)),
                        ("reqs", ArgV::S(reqs)),
                    ],
                );
            }

            // ---- post-wave bookkeeping (serial, entry order: results are
            // deterministic at any pool width). A failed entry fails only
            // its own request: retryable errors back the request off and
            // requeue it (bounded by `max_retries`, then structured
            // rejection); invariant breaches abort the serve call.
            let mut finished: Vec<usize> = Vec::new();
            let mut failed: Vec<usize> = Vec::new();
            for (entry, out) in entries.into_iter().zip(results) {
                match (entry, out) {
                    (WaveEntry::Prefill { p, bucket, resumed, .. }, Err(e)) => {
                        recorder.record_error(e.kind());
                        if !e.retryable() {
                            return Err(e.into());
                        }
                        // the attempt failed in isolation: restore any
                        // resume payload, then back off and retry
                        if let Some(r) = resumed {
                            resume.insert(p.idx, r);
                        }
                        if p.retries >= self.config.max_retries {
                            resume.remove(&p.idx);
                            recorder.shed += 1;
                            recorder.rejected += 1;
                            explain_admission(
                                &eng,
                                clock,
                                requests[p.idx].id,
                                "shed",
                                "retries_exhausted",
                                bucket,
                                p.depth,
                                0,
                                0,
                                self.config.budget_bytes,
                                0,
                                0,
                            );
                            responses.push(EngineResponse::rejected(
                                requests[p.idx].id,
                                p.depth,
                                RejectReason::RetriesExhausted,
                                clock,
                            ));
                        } else {
                            recorder.retries += 1;
                            explain_admission(
                                &eng,
                                clock,
                                requests[p.idx].id,
                                "backoff",
                                "fault_retry",
                                bucket,
                                p.depth,
                                0,
                                0,
                                self.config.budget_bytes,
                                0,
                                0,
                            );
                            requeue(
                                &mut queue,
                                requests,
                                clock,
                                Pending {
                                    idx: p.idx,
                                    depth: p.depth,
                                    evictions: p.evictions,
                                    retries: p.retries + 1,
                                    not_before: clock + backoff_ticks(p.retries + 1),
                                },
                            );
                        }
                    }
                    (WaveEntry::PrefillSlice { gi, .. }, Err(e)) => {
                        recorder.record_error(e.kind());
                        if !e.retryable() {
                            return Err(e.into());
                        }
                        // the generation's cache is unchanged (the slice
                        // never landed): retry through the same removal
                        // machinery as a failed decode step
                        failed.push(gi);
                    }
                    (WaveEntry::Decode { gi, .. }, Err(e)) => {
                        recorder.record_error(e.kind());
                        if !e.retryable() {
                            return Err(e.into());
                        }
                        // handled with finished removals below (indices
                        // into `gens` must shift together)
                        failed.push(gi);
                    }
                    (WaveEntry::DecodeBatched { gis, .. }, Err(e)) => {
                        recorder.record_error(e.kind());
                        if !e.retryable() {
                            return Err(e.into());
                        }
                        // A faulted/poisoned batched wave fails every
                        // member's *attempt*; each retries independently
                        // through the usual re-prefill resume machinery
                        // (decode parity keeps the recomputed streams
                        // bitwise identical). Requests outside this group
                        // are untouched — panic isolation is per entry.
                        failed.extend(gis);
                    }
                    (
                        WaveEntry::Prefill { p, bucket, h, lm: None, .. },
                        Ok(WaveOut::Plain { latency_us, out, stats }),
                    ) => {
                        recorder.absorb_exec(&stats);
                        if use_arena {
                            if let Some(a) = &mut auditor {
                                a.check_arena(
                                    recorder.waves,
                                    requests[p.idx].id,
                                    h.tag(),
                                    stats.arena_peak_bytes,
                                    h.memplan().planned_peak_bytes,
                                );
                            }
                        }
                        let req = &requests[p.idx];
                        let wait_ticks = clock - req.arrival_tick;
                        recorder.record(h.tag(), latency_us, req.seq_len);
                        if waited.insert(p.idx) {
                            recorder.record_wait(wait_ticks * tick_us);
                        }
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "complete",
                            "finished",
                            bucket,
                            p.depth,
                            0,
                            0,
                            self.config.budget_bytes,
                            0,
                            0,
                        );
                        responses.push(EngineResponse {
                            id: req.id,
                            outcome: RequestOutcome::Completed,
                            bucket,
                            depth: p.depth,
                            plan_tag: h.tag().to_string(),
                            wait_ticks,
                            latency_us,
                            output: out,
                            tokens: Vec::new(),
                            decode_steps: 0,
                            reason: None,
                            fault_touched: false,
                            finished_tick: clock,
                        });
                    }
                    (
                        WaveEntry::Prefill { p, bucket, h, lm: Some(_), ptoks, resumed },
                        Ok(WaveOut::Step { latency_us, outs, logits, token, stats }),
                    ) => {
                        recorder.absorb_exec(&stats);
                        if use_arena {
                            if let Some(a) = &mut auditor {
                                a.check_arena(
                                    recorder.waves,
                                    requests[p.idx].id,
                                    h.tag(),
                                    stats.arena_peak_bytes,
                                    h.memplan().planned_peak_bytes,
                                );
                            }
                        }
                        let req = &requests[p.idx];
                        let wait_ticks = clock - req.arrival_tick;
                        recorder.record_prefill(latency_us);
                        if resumed.is_none() && req.max_new_tokens == 1 {
                            // no decode needed: the prefill's token is it
                            recorder.record(h.tag(), latency_us, req.seq_len + 1);
                            if waited.insert(p.idx) {
                                recorder.record_wait(wait_ticks * tick_us);
                            }
                            recorder.record_ttft(wait_ticks * tick_us + latency_us);
                            explain_admission(
                                &eng,
                                clock,
                                req.id,
                                "complete",
                                "finished",
                                bucket,
                                p.depth,
                                0,
                                0,
                                self.config.budget_bytes,
                                0,
                                0,
                            );
                            responses.push(EngineResponse {
                                id: req.id,
                                outcome: RequestOutcome::Completed,
                                bucket,
                                depth: p.depth,
                                plan_tag: h.tag().to_string(),
                                wait_ticks,
                                latency_us,
                                output: logits,
                                tokens: vec![token],
                                decode_steps: 0,
                                reason: None,
                                fault_touched: false,
                                finished_tick: clock,
                            });
                        } else {
                            let plen = ptoks.len();
                            let cache = match &mut mgr {
                                Some(m) => match m.seed(bucket, &ptoks, plen, &outs) {
                                    Ok(tb) => GenCache::Paged(tb),
                                    Err(e) => {
                                        // The prefill ran but its blocks
                                        // never materialized (seed rolls
                                        // back): fail just this attempt.
                                        recorder.record_error(e.kind());
                                        if !e.retryable() {
                                            return Err(e.into());
                                        }
                                        if matches!(e, EngineError::Injected { .. }) {
                                            touched.insert(req.id);
                                        }
                                        drop(outs);
                                        if let Some(r) = resumed {
                                            resume.insert(p.idx, r);
                                        }
                                        if p.retries >= self.config.max_retries {
                                            resume.remove(&p.idx);
                                            recorder.shed += 1;
                                            recorder.rejected += 1;
                                            explain_admission(
                                                &eng,
                                                clock,
                                                req.id,
                                                "shed",
                                                "retries_exhausted",
                                                bucket,
                                                p.depth,
                                                0,
                                                0,
                                                self.config.budget_bytes,
                                                0,
                                                0,
                                            );
                                            responses.push(EngineResponse::rejected(
                                                req.id,
                                                p.depth,
                                                RejectReason::RetriesExhausted,
                                                clock,
                                            ));
                                        } else {
                                            recorder.retries += 1;
                                            explain_admission(
                                                &eng,
                                                clock,
                                                req.id,
                                                "backoff",
                                                "fault_retry",
                                                bucket,
                                                p.depth,
                                                0,
                                                0,
                                                self.config.budget_bytes,
                                                0,
                                                0,
                                            );
                                            requeue(
                                                &mut queue,
                                                requests,
                                                clock,
                                                Pending {
                                                    idx: p.idx,
                                                    depth: p.depth,
                                                    evictions: p.evictions,
                                                    retries: p.retries + 1,
                                                    not_before: clock
                                                        + backoff_ticks(p.retries + 1),
                                                },
                                            );
                                        }
                                        continue;
                                    }
                                },
                                None => {
                                    let Some(cfg) = gpt_cfg(&self.config.model, bucket) else {
                                        return Err(EngineError::NonGptGeneration.into());
                                    };
                                    let mut c = KvCache::new(
                                        cfg.layers,
                                        cfg.heads,
                                        bucket,
                                        cfg.head_dim(),
                                        Some(tracker.clone()),
                                    );
                                    for l in 0..cfg.layers {
                                        c.seed(l, &outs[1 + 2 * l], &outs[2 + 2 * l]);
                                    }
                                    c.set_len(plen);
                                    GenCache::Whole(c)
                                }
                            };
                            drop(outs);
                            let (tokens, decode_steps, last_emit) = match resumed {
                                Some(r) => {
                                    // decode parity: the re-prefill's last
                                    // row reproduces the evicted stream's
                                    // pending token bit for bit
                                    debug_assert_eq!(
                                        r.tokens.last().copied(),
                                        Some(token),
                                        "resume re-prefill diverged from the evicted stream"
                                    );
                                    (r.tokens, r.decode_steps, r.last_emit)
                                }
                                None => {
                                    recorder.record_ttft(wait_ticks * tick_us + latency_us);
                                    (vec![token], 0, Some(Instant::now()))
                                }
                            };
                            gens.push(GenState {
                                idx: p.idx,
                                bucket,
                                depth: p.depth,
                                plan_tag: h.tag().to_string(),
                                cache,
                                tokens,
                                past: plen,
                                plen,
                                ptoks: Vec::new(),
                                pending_resume: None,
                                last_logits: logits,
                                wait_ticks,
                                latency_us,
                                decode_steps,
                                last_emit,
                                evictions: p.evictions,
                                retries: p.retries,
                            });
                        }
                    }
                    (
                        WaveEntry::PrefillSlice { gi, n, h, .. },
                        Ok(WaveOut::Slice { latency_us, outs, logits, token, stats }),
                    ) => {
                        recorder.absorb_exec(&stats);
                        if use_arena {
                            if let Some(a) = &mut auditor {
                                a.check_arena(
                                    recorder.waves,
                                    requests[gens[gi].idx].id,
                                    h.tag(),
                                    stats.arena_peak_bytes,
                                    h.memplan().planned_peak_bytes,
                                );
                            }
                        }
                        recorder.record_prefill(latency_us);
                        let g = &mut gens[gi];
                        g.latency_us += latency_us;
                        g.plan_tag = h.tag().to_string();
                        match &mut g.cache {
                            GenCache::Whole(c) => {
                                for l in 0..c.layers() {
                                    c.append_rows(l, &outs[1 + 2 * l], &outs[2 + 2 * l]);
                                }
                                drop(outs);
                                c.advance_by(n);
                            }
                            GenCache::Paged(tb) => {
                                let Some(m) = mgr.as_mut() else {
                                    return Err(EngineError::MissingManager.into());
                                };
                                if let Err(e) = m.append_slice(tb, &outs, n) {
                                    // table rolled back to its pre-slice
                                    // state: drop this attempt and retry
                                    // through the eviction machinery
                                    recorder.record_error(e.kind());
                                    if !e.retryable() {
                                        return Err(e.into());
                                    }
                                    if matches!(e, EngineError::Injected { .. }) {
                                        touched.insert(requests[g.idx].id);
                                    }
                                    drop(outs);
                                    failed.push(gi);
                                    continue;
                                }
                                drop(outs);
                            }
                            GenCache::Spilled(_) => {
                                return Err(EngineError::WaveMismatch.into());
                            }
                        }
                        g.past += n;
                        if let Some(token) = token {
                            // final slice: prefill complete, decode starts
                            debug_assert_eq!(
                                g.past, g.plen,
                                "LM head ran before the prefill finished"
                            );
                            g.last_logits = logits.unwrap_or_default();
                            match g.pending_resume.take() {
                                Some(r) => {
                                    // decode parity: the re-prefill's last
                                    // row reproduces the evicted stream's
                                    // pending token bit for bit
                                    debug_assert_eq!(
                                        r.tokens.last().copied(),
                                        Some(token),
                                        "resume re-prefill diverged from the evicted stream"
                                    );
                                    g.tokens = r.tokens;
                                    g.decode_steps = r.decode_steps;
                                    g.last_emit = r.last_emit;
                                }
                                None => {
                                    g.tokens = vec![token];
                                    recorder
                                        .record_ttft(g.wait_ticks * tick_us + g.latency_us);
                                    g.last_emit = Some(Instant::now());
                                }
                            }
                            g.ptoks = Vec::new();
                            if g.tokens.len() >= requests[g.idx].max_new_tokens {
                                finished.push(gi);
                            }
                        }
                    }
                    (
                        WaveEntry::Decode { gi, h, .. },
                        Ok(WaveOut::Step { latency_us, outs, logits, token, stats }),
                    ) => {
                        recorder.absorb_exec(&stats);
                        if use_arena {
                            if let Some(a) = &mut auditor {
                                a.check_arena(
                                    recorder.waves,
                                    requests[gens[gi].idx].id,
                                    h.tag(),
                                    stats.arena_peak_bytes,
                                    h.memplan().planned_peak_bytes,
                                );
                            }
                        }
                        recorder.record_decode(latency_us);
                        let g = &mut gens[gi];
                        g.latency_us += latency_us;
                        match &mut g.cache {
                            GenCache::Whole(c) => {
                                for l in 0..c.layers() {
                                    c.append(l, &outs[1 + 2 * l], &outs[2 + 2 * l]);
                                }
                                drop(outs);
                                c.advance();
                            }
                            GenCache::Paged(tb) => {
                                let Some(m) = mgr.as_mut() else {
                                    return Err(EngineError::MissingManager.into());
                                };
                                if let Err(e) = m.append_step(tb, &outs) {
                                    // table unchanged (append is atomic):
                                    // drop this step and recompute the
                                    // stream via the eviction machinery
                                    recorder.record_error(e.kind());
                                    if !e.retryable() {
                                        return Err(e.into());
                                    }
                                    if matches!(e, EngineError::Injected { .. }) {
                                        touched.insert(requests[g.idx].id);
                                    }
                                    drop(outs);
                                    failed.push(gi);
                                    continue;
                                }
                                drop(outs);
                            }
                            GenCache::Spilled(_) => {
                                return Err(EngineError::WaveMismatch.into());
                            }
                        }
                        g.past += 1;
                        g.tokens.push(token);
                        let now = Instant::now();
                        if let Some(prev) = g.last_emit {
                            recorder.record_itl(now.duration_since(prev).as_micros() as u64);
                        }
                        g.last_emit = Some(now);
                        g.last_logits = logits;
                        g.decode_steps += 1;
                        if g.tokens.len() >= requests[g.idx].max_new_tokens {
                            finished.push(gi);
                        }
                    }
                    (
                        WaveEntry::DecodeBatched { gis, h, .. },
                        Ok(WaveOut::StepBatch { latency_us, outs, mut logits, tokens, stats }),
                    ) => {
                        recorder.absorb_exec(&stats);
                        if use_arena {
                            if let Some(a) = &mut auditor {
                                a.check_arena(
                                    recorder.waves,
                                    requests[gens[gis[0]].idx].id,
                                    h.tag(),
                                    stats.arena_peak_bytes,
                                    h.memplan().planned_peak_bytes,
                                );
                            }
                        }
                        // Scatter the stacked step back to its members:
                        // column j of each K/V output is member j's new
                        // cache row, logits/tokens row j its sampled step.
                        let layers = (outs.len() - 1) / 2;
                        for (j, &gi) in gis.iter().enumerate() {
                            recorder.record_decode(latency_us);
                            let g = &mut gens[gi];
                            g.latency_us += latency_us;
                            match &mut g.cache {
                                GenCache::Whole(c) => {
                                    for l in 0..c.layers() {
                                        c.append(
                                            l,
                                            &outs[1 + 2 * l].slice_axis(1, j, 1),
                                            &outs[2 + 2 * l].slice_axis(1, j, 1),
                                        );
                                    }
                                    c.advance();
                                }
                                GenCache::Paged(tb) => {
                                    let Some(m) = mgr.as_mut() else {
                                        return Err(EngineError::MissingManager.into());
                                    };
                                    // append_step wants the looped step's
                                    // output arity: slice this member's
                                    // column out of each stacked output
                                    let mut member_outs: Vec<Tensor> =
                                        Vec::with_capacity(outs.len());
                                    member_outs.push(outs[0].slice_axis(0, j, 1));
                                    for l in 0..layers {
                                        member_outs.push(outs[1 + 2 * l].slice_axis(1, j, 1));
                                        member_outs.push(outs[2 + 2 * l].slice_axis(1, j, 1));
                                    }
                                    if let Err(e) = m.append_step(tb, &member_outs) {
                                        // table unchanged (append is
                                        // atomic): drop this member's step
                                        // only — siblings already appended
                                        // keep theirs
                                        recorder.record_error(e.kind());
                                        if !e.retryable() {
                                            return Err(e.into());
                                        }
                                        if matches!(e, EngineError::Injected { .. }) {
                                            touched.insert(requests[g.idx].id);
                                        }
                                        failed.push(gi);
                                        continue;
                                    }
                                }
                                GenCache::Spilled(_) => {
                                    return Err(EngineError::WaveMismatch.into());
                                }
                            }
                            g.past += 1;
                            g.tokens.push(tokens[j]);
                            let now = Instant::now();
                            if let Some(prev) = g.last_emit {
                                recorder
                                    .record_itl(now.duration_since(prev).as_micros() as u64);
                            }
                            g.last_emit = Some(now);
                            g.last_logits = std::mem::take(&mut logits[j]);
                            g.decode_steps += 1;
                            if g.tokens.len() >= requests[g.idx].max_new_tokens {
                                finished.push(gi);
                            }
                        }
                        drop(outs);
                    }
                    _ => return Err(EngineError::WaveMismatch.into()),
                }
            }

            // High-water resident KV — true residency under either
            // backend (blocks in use for the pool, held capacity for
            // contiguous caches) — and co-resident generation count:
            // after this wave's caches were seeded, before finished
            // generations evict.
            let resident_now: usize = match &mgr {
                Some(m) => m.resident_bytes(),
                None => gens
                    .iter()
                    .map(|g| match &g.cache {
                        GenCache::Whole(c) => c.resident_bytes(),
                        GenCache::Paged(_) => 0,
                        GenCache::Spilled(_) => 0,
                    })
                    .sum(),
            };
            recorder.observe_resident_kv(resident_now);
            recorder.observe_concurrent_gens(gens.len());

            // Eviction: finished generations release their caches (and
            // their resident bytes or blocks) immediately; failed decode
            // steps release theirs and requeue through the re-prefill
            // resume path. One descending pass so removals don't shift
            // indices still pending removal.
            let mut removals: Vec<(usize, bool)> =
                finished.into_iter().map(|gi| (gi, true)).collect();
            removals.extend(failed.into_iter().map(|gi| (gi, false)));
            removals.sort_unstable_by_key(|&(gi, _)| gi);
            for &(gi, done) in removals.iter().rev() {
                let g = gens.remove(gi);
                if done {
                    match g.cache {
                        GenCache::Paged(tb) => match mgr.as_mut() {
                            Some(m) => m.release_table(tb),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Spilled(st) => match mgr.as_ref() {
                            Some(m) => m.discard_spilled(st),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Whole(_) => {}
                    }
                    let req = &requests[g.idx];
                    recorder.record(
                        g.plan_tag.as_str(),
                        g.latency_us,
                        req.seq_len + g.tokens.len(),
                    );
                    if waited.insert(g.idx) {
                        recorder.record_wait(g.wait_ticks * tick_us);
                    }
                    explain_admission(
                        &eng,
                        clock,
                        req.id,
                        "complete",
                        "finished",
                        g.bucket,
                        g.depth,
                        0,
                        0,
                        self.config.budget_bytes,
                        0,
                        0,
                    );
                    responses.push(EngineResponse {
                        id: req.id,
                        outcome: RequestOutcome::Completed,
                        bucket: g.bucket,
                        depth: g.depth,
                        plan_tag: g.plan_tag,
                        wait_ticks: g.wait_ticks,
                        latency_us: g.latency_us,
                        output: g.last_logits,
                        tokens: g.tokens,
                        decode_steps: g.decode_steps,
                        reason: None,
                        fault_touched: false,
                        finished_tick: clock,
                    });
                } else {
                    // A failed decode attempt: release the cache exactly
                    // (blocks and plan-cache pins), then retry through
                    // re-prefill recompute — decode parity makes the
                    // resumed stream bitwise identical — or shed after
                    // max_retries.
                    match g.cache {
                        GenCache::Paged(tb) => match mgr.as_mut() {
                            Some(m) => m.release_table(tb),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Spilled(st) => match mgr.as_ref() {
                            Some(m) => m.discard_spilled(st),
                            None => return Err(EngineError::MissingManager.into()),
                        },
                        GenCache::Whole(_) => {}
                    }
                    let req = &requests[g.idx];
                    if g.retries >= self.config.max_retries {
                        recorder.shed += 1;
                        recorder.rejected += 1;
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "shed",
                            "retries_exhausted",
                            g.bucket,
                            g.depth,
                            0,
                            0,
                            self.config.budget_bytes,
                            0,
                            0,
                        );
                        responses.push(EngineResponse::rejected(
                            req.id,
                            g.depth,
                            RejectReason::RetriesExhausted,
                            clock,
                        ));
                    } else {
                        recorder.retries += 1;
                        explain_admission(
                            &eng,
                            clock,
                            req.id,
                            "backoff",
                            "fault_retry",
                            g.bucket,
                            g.depth,
                            0,
                            0,
                            self.config.budget_bytes,
                            0,
                            0,
                        );
                        if g.tokens.is_empty() {
                            // Failed mid-prefill: no stream state of its
                            // own yet — restore the resume payload (if
                            // any) it was admitted with, untouched.
                            if let Some(r) = g.pending_resume {
                                resume.insert(g.idx, r);
                            }
                        } else {
                            resume.insert(
                                g.idx,
                                ResumeState {
                                    tokens: g.tokens,
                                    decode_steps: g.decode_steps,
                                    last_emit: g.last_emit,
                                },
                            );
                        }
                        requeue(
                            &mut queue,
                            requests,
                            clock,
                            Pending {
                                idx: g.idx,
                                depth: g.depth,
                                evictions: g.evictions,
                                retries: g.retries + 1,
                                not_before: clock + backoff_ticks(g.retries + 1),
                            },
                        );
                    }
                }
            }

            // Memory timeline sample (one per wave tick, post-removals):
            // resident KV and scheduler occupancy, both schedule-exact and
            // pool-width-independent — the trace's Perfetto counter tracks.
            if let Some(s) = &eng {
                let resident_after: usize = match &mgr {
                    Some(m) => m.resident_bytes(),
                    None => gens
                        .iter()
                        .map(|g| match &g.cache {
                            GenCache::Whole(c) => c.resident_bytes(),
                            GenCache::Paged(_) | GenCache::Spilled(_) => 0,
                        })
                        .sum(),
                };
                s.counter(
                    "memory",
                    vec![
                        ("tick", ArgV::U(clock)),
                        ("resident_kv", ArgV::U(resident_after as u64)),
                        (
                            "blocks_in_use",
                            ArgV::U(mgr.as_ref().map(|m| m.blocks_in_use()).unwrap_or(0) as u64),
                        ),
                    ],
                );
                s.counter(
                    "sched",
                    vec![
                        ("tick", ArgV::U(clock)),
                        ("queued", ArgV::U(queue.len() as u64)),
                        ("running", ArgV::U(gens.len() as u64)),
                        ("responded", ArgV::U(responses.len() as u64)),
                    ],
                );
            }

            // Invariant audit (between waves the engine is quiescent: the
            // only live tracked allocations are resident KV caches).
            if let Some(a) = &mut auditor {
                let expected_kv: usize = match &mgr {
                    Some(m) => m.resident_bytes(),
                    None => gens
                        .iter()
                        .map(|g| match &g.cache {
                            GenCache::Whole(c) => c.capacity_bytes(),
                            GenCache::Paged(_) => 0,
                            GenCache::Spilled(_) => 0,
                        })
                        .sum(),
                };
                let pool_state =
                    mgr.as_ref().map(|m| (m.blocks_in_use(), m.free_blocks(), m.pool_blocks()));
                let queued: Vec<usize> = queue.iter().map(|p| requests[p.idx].id).collect();
                let running: Vec<usize> = gens.iter().map(|g| requests[g.idx].id).collect();
                let done: Vec<usize> = responses.iter().map(|r| r.id).collect();
                let av0 = a.violations().len();
                a.check_wave(
                    recorder.waves,
                    tracker.current(),
                    expected_kv,
                    pool_state,
                    &queued,
                    &running,
                    &done,
                    requests.len(),
                );
                // Auditor context (satellite 1): every violation found
                // this wave lands in the trace as an instant, tagged with
                // the wave tick.
                if let Some(s) = &eng {
                    for v in &a.violations()[av0..] {
                        s.instant(
                            "audit.violation",
                            vec![
                                ("tick", ArgV::U(clock)),
                                ("wave", ArgV::U(recorder.waves as u64)),
                                ("msg", ArgV::S(v.clone())),
                            ],
                        );
                    }
                }
            }

            recorder.waves += 1;
            clock += 1;
        }

        debug_assert!(gens.is_empty(), "serve loop exited with live generations");
        debug_assert!(resume.is_empty(), "serve loop exited with pending resumes");
        recorder.cache_hits = self.cache_hits - hits0;
        recorder.cache_misses = self.cache_misses - miss0;
        // Terminal audit: every request in a terminal state, every block
        // and tracked byte returned.
        if let Some(a) = &mut auditor {
            let av0 = a.violations().len();
            a.check_terminal(
                tracker.current(),
                mgr.as_ref().map(|m| m.blocks_in_use()).unwrap_or(0),
                gens.len(),
                resume.len(),
                queue.len(),
                responses.len(),
                requests.len(),
            );
            if let Some(s) = &eng {
                for v in &a.violations()[av0..] {
                    s.instant(
                        "audit.violation",
                        vec![
                            ("tick", ArgV::U(clock)),
                            ("wave", ArgV::U(recorder.waves as u64)),
                            ("msg", ArgV::S(v.clone())),
                        ],
                    );
                }
            }
        }
        if let Some(a) = auditor {
            let rep = a.into_report();
            recorder.waves_audited = rep.waves_audited;
            recorder.audit_violations = rep.violations.len();
            recorder.audit_log = rep.violations;
        }
        if let Some(plan) = &faults {
            recorder.fault_injections = plan.total_fired();
        }
        for r in &mut responses {
            r.fault_touched = touched.contains(&r.id);
        }
        if let Some(m) = &mgr {
            // Drain contract: every block returned to the free list.
            recorder.shared_prefix_hits = m.shared_hits();
            recorder.final_blocks_in_use = m.blocks_in_use();
            debug_assert_eq!(m.blocks_in_use(), 0, "paged pool leaked blocks at drain");
        }
        drop(mgr);
        recorder.measured_peak_bytes = tracker.peak();
        recorder.measured_final_bytes = tracker.current();
        responses.sort_by_key(|r| r.id);
        // Trace export: keep the recorded trace on the engine for
        // [`ServeEngine::take_trace`]; when `AUTOCHUNK_TRACE=<path>` is
        // set, also write the Chrome trace-event JSON now so even a run
        // that never touches the API leaves a loadable artifact.
        self.trace_compile = None;
        if let Some(t) = &tr {
            if let Some(path) = trace::trace_path_from_env() {
                if let Err(e) = std::fs::write(path, t.chrome_json()) {
                    eprintln!("autochunk: failed to write trace to {path}: {e}");
                }
            }
        }
        self.trace = tr;
        let report = recorder.finish(t0.elapsed());
        Ok((responses, report))
    }
}

/// Emit one [`AdmissionExplain`] instant on the engine lane — the priced
/// record of a scheduler decision (admit/defer/deepen/shed/spill/evict/
/// restore/backoff/complete), a single `None` branch when tracing is off.
#[allow(clippy::too_many_arguments)]
fn explain_admission(
    scope: &Option<TraceScope>,
    tick: u64,
    request: usize,
    decision: &'static str,
    reason: &'static str,
    bucket: usize,
    depth: usize,
    cost_bytes: usize,
    remaining_bytes: usize,
    budget_bytes: usize,
    need_blocks: usize,
    free_blocks: usize,
) {
    if let Some(s) = scope {
        AdmissionExplain {
            tick,
            request,
            decision,
            reason,
            bucket,
            depth,
            cost_bytes,
            remaining_bytes,
            budget_bytes,
            need_blocks,
            free_blocks,
        }
        .emit(s);
    }
}

/// Build a model graph at a bucket's scale (per-model interpretation:
/// tokens, patches, residues, image side).
fn build_model(name: &str, scale: usize) -> Result<Graph> {
    Ok(match name {
        "gpt" => models::gpt(&models::GptConfig { seq: scale, ..Default::default() }),
        "gpt-fused" => models::gpt(&models::GptConfig {
            seq: scale,
            fused_attention: true,
            ..Default::default()
        }),
        "vit" => models::vit(&models::ViTConfig { patches: scale, ..Default::default() }),
        "evoformer" => {
            models::evoformer(&models::EvoformerConfig { seq: scale, ..Default::default() })
        }
        "unet" => models::unet(&models::UNetConfig { image: scale, ..Default::default() }),
        other => crate::bail!("unknown model '{other}' (gpt|gpt-fused|vit|evoformer|unet)"),
    })
}

/// Deterministically materialize graph inputs from a token stream: token
/// ids feed i32 inputs directly (zero-padded to the bucket); f32 inputs
/// derive a repeatable pattern from the tokens. Allocated on the run's
/// tracker so request inputs count as activation memory, as in
/// production. Generative prefills call this with the *effective* prompt
/// (post-eviction resumes extend the request's tokens with generated
/// ones).
fn prompt_inputs(graph: &Graph, tokens: &[i32], tracker: &MemoryTracker) -> Vec<Tensor> {
    graph
        .inputs
        .iter()
        .map(|&id| {
            let node = graph.node(id);
            let count = numel(&node.shape);
            match node.dtype {
                DType::I32 => {
                    let v = pad_prompt(tokens, count);
                    Tensor::from_i32(v, &node.shape, Some(tracker.clone()))
                }
                DType::F32 => {
                    let mut v = vec![0f32; count];
                    for (i, slot) in v.iter_mut().enumerate() {
                        let t = if tokens.is_empty() {
                            (i % 97) as i32
                        } else {
                            tokens[i % tokens.len()]
                        };
                        *slot = (t % 512) as f32 / 512.0 - 0.5;
                    }
                    Tensor::from_f32(v, &node.shape, Some(tracker.clone()))
                }
            }
        })
        .collect()
}

/// [`prompt_inputs`] over a request's own tokens (the non-generative
/// prefill path).
fn request_inputs(graph: &Graph, req: &Request, tracker: &MemoryTracker) -> Vec<Tensor> {
    prompt_inputs(graph, &req.tokens, tracker)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine(budget: usize) -> ServeEngine {
        ServeEngine::new(EngineConfig {
            model: "gpt".into(),
            budget_bytes: budget,
            max_batch: 4,
            buckets: vec![16, 32],
            worker_threads: 1,
            // these module tests assert looped-path metrics (dispatch
            // counts, per-step latencies); the batched default is covered
            // by the integration suite and the CI matrix axis
            batch_decode: false,
            ..EngineConfig::default()
        })
    }

    #[test]
    fn bucket_routing() {
        let e = tiny_engine(1 << 30);
        assert_eq!(e.bucket_for(10), Some(16));
        assert_eq!(e.bucket_for(16), Some(16));
        assert_eq!(e.bucket_for(17), Some(32));
        assert_eq!(e.bucket_for(33), None);
    }

    #[test]
    fn quote_compiles_once_per_bucket() {
        let mut e = tiny_engine(1 << 30);
        let (b1, q1) = e.quote(10, 0).unwrap().unwrap();
        let (b2, q2) = e.quote(12, 0).unwrap().unwrap();
        assert_eq!(b1, 16);
        assert_eq!(b2, 16);
        assert_eq!(q1.peak_bytes, q2.peak_bytes);
        let (hits, misses) = e.cache_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 1);
        assert!(e.registry().get("gpt_native_s16_d0").is_some());
    }

    #[test]
    fn too_long_request_rejected() {
        let mut e = tiny_engine(1 << 30);
        let reqs = vec![Request::new(0, 64, 1)];
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].outcome, RequestOutcome::Rejected);
        assert_eq!(report.rejected, 1);
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn small_workload_completes() {
        let mut e = tiny_engine(1 << 30);
        let reqs: Vec<Request> =
            (0..3).map(|i| Request::new(i, 8 + i * 4, i as i32).at_tick(0, 500)).collect();
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert!(resp.iter().all(|r| r.outcome == RequestOutcome::Completed));
        assert_eq!(report.completed, 3);
        assert!(report.measured_peak_bytes > 0);
        assert!(report.measured_peak_bytes <= 1 << 30);
        // ids come back sorted
        let ids: Vec<usize> = resp.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &resp {
            assert!(!r.output.is_empty());
            assert!(r.output.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn generation_produces_tokens_and_evicts() {
        let mut e = tiny_engine(1 << 30);
        let reqs = vec![Request::new(0, 6, 3).generate(4).at_tick(0, 500)];
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp.len(), 1);
        let r = &resp[0];
        assert_eq!(r.outcome, RequestOutcome::Completed);
        assert_eq!(r.tokens.len(), 4, "{:?}", r.tokens);
        assert_eq!(r.decode_steps, 3);
        assert!(r.plan_tag.contains("prefill"), "{}", r.plan_tag);
        assert!(r.output.iter().all(|x| x.is_finite()));
        // metrics: decode breakdown + resident high water, evicted at end
        assert_eq!(report.generated_tokens, 3, "decode-step tokens");
        assert!(report.decode_p99_us >= report.decode_p50_us);
        assert!(report.prefill_p99_us > 0);
        let kv = e.kv_bytes(16);
        assert!(kv > 0);
        assert_eq!(report.resident_kv_high_water_bytes, kv);
        assert!(report.measured_peak_bytes >= kv);
        assert_eq!(report.measured_final_bytes, 0, "cache not evicted");
    }

    #[test]
    fn generation_routes_by_total_footprint() {
        let mut e = tiny_engine(1 << 30);
        // prompt 12 fits bucket 16, but 12 + 7 fed-back positions (8
        // generated, the last never re-embedded) needs bucket 32
        let reqs = vec![Request::new(0, 12, 1).generate(8)];
        let (resp, _) = e.serve(&reqs).unwrap();
        assert_eq!(resp[0].outcome, RequestOutcome::Completed);
        assert_eq!(resp[0].bucket, 32);
        // and an over-capacity generation is rejected outright
        let reqs = vec![Request::new(1, 30, 1).generate(8)];
        let (resp, _) = e.serve(&reqs).unwrap();
        assert_eq!(resp[0].outcome, RequestOutcome::Rejected);
    }

    #[test]
    fn single_token_generation_skips_decode() {
        let mut e = tiny_engine(1 << 30);
        let reqs = vec![Request::new(0, 8, 2).generate(1)];
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp[0].tokens.len(), 1);
        assert_eq!(resp[0].decode_steps, 0);
        assert_eq!(report.generated_tokens, 0, "no decode steps ran");
        assert_eq!(report.resident_kv_high_water_bytes, 0, "no cache bound");
    }

    #[test]
    fn generation_on_non_gpt_model_rejected() {
        let mut e = ServeEngine::new(EngineConfig {
            model: "vit".into(),
            budget_bytes: 1 << 30,
            buckets: vec![16],
            worker_threads: 1,
            ..EngineConfig::default()
        });
        let reqs = vec![Request::new(0, 8, 1).generate(2)];
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp[0].outcome, RequestOutcome::Rejected);
        assert_eq!(report.rejected, 1);
    }

    #[test]
    fn unknown_model_errors() {
        assert!(build_model("nope", 16).is_err());
    }

    #[test]
    fn deadline_tick_itself_is_still_valid() {
        // expiry is strictly *after* arrival + deadline: completing ON
        // the deadline tick meets the SLO
        let req = Request::new(0, 8, 1).at_tick(5, 500).deadline(10);
        assert!(!deadline_expired(15, &req), "the deadline tick is valid");
        assert!(deadline_expired(16, &req), "one past the deadline is not");
        assert!(!deadline_expired(5, &req));
    }

    #[test]
    fn zero_deadline_means_none() {
        let req = Request::new(0, 8, 1).at_tick(5, 500);
        assert!(!deadline_expired(u64::MAX, &req));
    }

    #[test]
    fn huge_deadline_saturates_instead_of_wrapping() {
        // pre-fix, arrival 5 + u64::MAX wrapped to 4 and the request was
        // shed on arrival (clock 5 > 4); saturating_add pins "never"
        let req = Request::new(0, 8, 1).at_tick(5, 500).deadline(u64::MAX);
        assert!(!deadline_expired(5, &req));
        assert!(!deadline_expired(u64::MAX, &req));
    }

    #[test]
    fn backoff_ladder_is_pinned() {
        let ladder: Vec<u64> = (0..12).map(backoff_ticks).collect();
        assert_eq!(ladder, vec![0, 0, 1, 2, 4, 8, 16, 32, 64, 64, 64, 64]);
    }

    fn pending(idx: usize) -> Pending {
        Pending { idx, depth: 0, evictions: 0, retries: 0, not_before: 0 }
    }

    #[test]
    fn requeue_respects_priority_over_retry_head_position() {
        // pre-fix, push_front let a low-priority deepening retry (idx 0)
        // jump the queued priority-5 arrival (idx 1)
        let requests = vec![
            Request::new(0, 8, 0).at_tick(0, 500),
            Request::new(1, 8, 0).at_tick(0, 500).with_priority(5),
            Request::new(2, 8, 0).at_tick(0, 500),
        ];
        let mut queue: VecDeque<Pending> = VecDeque::from(vec![pending(1), pending(2)]);
        requeue(&mut queue, &requests, 0, pending(0));
        let order: Vec<usize> = queue.iter().map(|p| p.idx).collect();
        assert_eq!(order, vec![1, 0, 2], "retry heads its own class only");
    }

    #[test]
    fn requeue_reduces_to_head_insert_for_uniform_class() {
        // no priorities, no deadlines: the legacy head-of-queue retry
        // position is preserved exactly
        let requests: Vec<Request> =
            (0..3).map(|i| Request::new(i, 8, 0).at_tick(0, 500)).collect();
        let mut queue: VecDeque<Pending> = VecDeque::from(vec![pending(1), pending(2)]);
        requeue(&mut queue, &requests, 0, pending(0));
        let order: Vec<usize> = queue.iter().map(|p| p.idx).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn requeue_prefers_tighter_deadline_within_class() {
        let requests = vec![
            Request::new(0, 8, 0).at_tick(0, 500).deadline(20),
            Request::new(1, 8, 0).at_tick(0, 500).deadline(5),
        ];
        let mut queue: VecDeque<Pending> = VecDeque::from(vec![pending(1)]);
        requeue(&mut queue, &requests, 0, pending(0));
        let order: Vec<usize> = queue.iter().map(|p| p.idx).collect();
        assert_eq!(order, vec![1, 0], "slack 5 stays ahead of slack 20");
    }

    #[test]
    fn stall_spill_restores_stream_bitwise_vs_eviction() {
        // Two generative streams on a 2-block pool: co-residency needs 4
        // blocks, so one stream must give way. The eviction leg recomputes
        // it from scratch; the spill leg parks its blocks in the slow tier
        // and restores them. Token streams are schedule-independent, so
        // the legs must agree bit for bit.
        let serve = |gbps: f64| {
            let mut e = ServeEngine::new(EngineConfig {
                model: "gpt".into(),
                budget_bytes: 1 << 30,
                max_batch: 4,
                buckets: vec![16],
                worker_threads: 1,
                batch_decode: false,
                block_tokens: 8,
                pool_blocks: 2,
                spill_gbps: gbps,
                ..EngineConfig::default()
            });
            let reqs: Vec<Request> =
                (0..2).map(|i| Request::new(i, 8, i as i32).generate(4).at_tick(0, 500)).collect();
            e.serve(&reqs).unwrap()
        };
        let (evict_resp, evict_rep) = serve(0.0);
        let (spill_resp, spill_rep) = serve(8.0);
        assert!(evict_rep.evicted >= 1, "eviction leg must actually evict");
        assert!(spill_rep.kv_spills >= 1, "spill leg parks at least one table");
        assert_eq!(spill_rep.evicted, 0, "spill leg never discards blocks");
        assert_eq!(spill_rep.kv_restores, spill_rep.kv_spills, "every parked table revives");
        for (a, b) in evict_resp.iter().zip(spill_resp.iter()) {
            assert_eq!(a.outcome, RequestOutcome::Completed);
            assert_eq!(b.outcome, RequestOutcome::Completed);
            assert_eq!(a.tokens, b.tokens, "req {}: token stream diverged", a.id);
            assert_eq!(a.output, b.output, "req {}: final logits diverged", a.id);
        }
    }
}
