//! Continuous-batching serve engine over the native compiler stack.
//!
//! The PR-1 `Coordinator` served a *closed* workload through AOT/PJRT
//! artifacts in one shot. This engine is the production shape the paper's
//! runtime half points at (DESIGN.md §11):
//!
//! * **request queue with arrival ticks** — an open-loop trace replayed on
//!   a deterministic virtual clock, so admission pressure is part of the
//!   workload and results are machine-independent;
//! * **memory-aware admission** — each wave is packed greedily by the
//!   estimator's [`CostQuote`] (`peak + (d−1)·per_chunk`, the PR-1
//!   governor formula) against the global `budget_bytes`, not by request
//!   count: activation memory, not parameters, is the binding constraint;
//! * **per-bucket compiled-plan caching** — a (model, seq-bucket, depth)
//!   triple is chunk-searched once and the resulting [`PlanHandle`] is
//!   shared by every subsequent request in that bucket;
//! * **preemption instead of rejection** — a request whose quote exceeds
//!   the budget is requeued (with head priority) for a deeper-chunked
//!   recompile; only when the deepest level still does not fit is it
//!   rejected ("the memory wall").
//!
//! Determinism contract: at `AUTOCHUNK_THREADS=1` the engine's responses
//! are bitwise identical to the legacy back-to-back path
//! ([`ServeEngine::serve_serial`]); at any width they remain bitwise
//! identical because every parallel region in the stack decomposes over
//! disjoint output slabs (DESIGN.md §8).

use crate::coordinator::metrics::{MetricsReport, Recorder};
use crate::coordinator::request::{Request, RequestOutcome};
use crate::exec::random_params;
use crate::ir::Graph;
use crate::models;
use crate::passes::{autochunk, estimate, AutoChunkConfig, CostQuote};
use crate::plan::{ExecOptions, PlanHandle};
use crate::runtime::{ArtifactMeta, Registry};
use crate::tensor::{numel, DType, MemoryTracker, Tensor};
use crate::util::error::Result;
use crate::util::pool;
use std::collections::{HashMap, VecDeque};
use std::time::Instant;

/// Configuration of the continuous-batching engine.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Model family: `gpt` | `gpt-fused` | `vit` | `evoformer` | `unet`.
    pub model: String,
    /// Global activation-memory budget (bytes) each wave is packed under.
    pub budget_bytes: usize,
    /// Max co-resident requests per wave regardless of memory.
    pub max_batch: usize,
    /// Sequence buckets (ascending); a request routes to the smallest
    /// bucket that holds it. Per-model scale knob (tokens, patches,
    /// residues, image side).
    pub buckets: Vec<usize>,
    /// Pool width while serving (0 = inherit `AUTOCHUNK_THREADS`).
    pub worker_threads: usize,
    /// How many deeper-chunked recompiles an oversized request may retry
    /// before rejection. Level `d ≥ 1` compiles at a `baseline >> d`
    /// target; level 0 is the dense (unchunked) plan.
    pub max_deepen: usize,
    /// Virtual duration of one queue tick (metrics only).
    pub tick_us: u64,
    /// Serve through the planned-allocation arena executor and price
    /// admission with the memory planner's *exact* `admission_bytes`
    /// instead of the pessimistic quote (the quote stays a cross-check
    /// ceiling). Defaults to the `AUTOCHUNK_ARENA` env flag — the CI
    /// matrix's second leg.
    pub use_arena: bool,
    /// Compiler options for the per-bucket chunk search.
    pub compile: AutoChunkConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "gpt".into(),
            budget_bytes: 64 << 20,
            max_batch: 8,
            buckets: vec![64, 128, 256],
            worker_threads: 0,
            max_deepen: 5,
            tick_us: 500,
            use_arena: crate::plan::arena_default(),
            compile: AutoChunkConfig::default(),
        }
    }
}

/// The engine's answer for one request. Carries the full model output so
/// determinism can be asserted bitwise against the serial path.
#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: usize,
    pub outcome: RequestOutcome,
    /// Sequence bucket the request was served in (0 when rejected).
    pub bucket: usize,
    /// Chunk-deepening level of the plan that served it.
    pub depth: usize,
    /// Tag of the cached plan (empty when rejected).
    pub plan_tag: String,
    /// Queueing delay in ticks between arrival and admission.
    pub wait_ticks: u64,
    pub latency_us: u64,
    /// Flattened first model output (empty when rejected).
    pub output: Vec<f32>,
}

impl EngineResponse {
    fn rejected(id: usize, depth: usize) -> EngineResponse {
        EngineResponse {
            id,
            outcome: RequestOutcome::Rejected,
            bucket: 0,
            depth,
            plan_tag: String::new(),
            wait_ticks: 0,
            latency_us: 0,
            output: Vec::new(),
        }
    }
}

/// A queued request: its index into the workload plus the deepening level
/// the next admission attempt will use.
#[derive(Clone, Copy, Debug)]
struct Pending {
    idx: usize,
    depth: usize,
}

#[derive(Clone, Copy)]
enum Mode {
    Continuous,
    Serial,
}

/// Continuous-batching serve engine (native interpreter backend).
pub struct ServeEngine {
    config: EngineConfig,
    cache: HashMap<(usize, usize), PlanHandle>,
    params: HashMap<usize, Vec<Tensor>>,
    /// Unchunked estimated peak per bucket (the deepening ladder's base),
    /// computed once per bucket rather than once per (bucket, depth).
    baselines: HashMap<usize, usize>,
    registry: Registry,
    cache_hits: usize,
    cache_misses: usize,
}

impl ServeEngine {
    pub fn new(mut config: EngineConfig) -> ServeEngine {
        config.buckets.sort_unstable();
        config.buckets.dedup();
        ServeEngine {
            config,
            cache: HashMap::new(),
            params: HashMap::new(),
            baselines: HashMap::new(),
            registry: Registry::in_memory(),
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Catalog of every variant compiled so far (native tags).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// (hits, misses) of the compiled-plan cache since construction.
    pub fn cache_stats(&self) -> (usize, usize) {
        (self.cache_hits, self.cache_misses)
    }

    /// Smallest bucket that holds `seq_len` (None if longer than all).
    pub fn bucket_for(&self, seq_len: usize) -> Option<usize> {
        self.config.buckets.iter().copied().find(|&b| b >= seq_len)
    }

    /// Per-request cost quote at a deepening level: what admission control
    /// would charge a request of `seq_len` (compiling and caching the
    /// bucket's plan if needed).
    pub fn quote(&mut self, seq_len: usize, depth: usize) -> Result<Option<(usize, CostQuote)>> {
        let Some(bucket) = self.bucket_for(seq_len) else {
            return Ok(None);
        };
        let h = self.handle(bucket, depth)?;
        Ok(Some((bucket, *h.quote())))
    }

    /// Compile (once) and cache the plan for a (bucket, depth) pair.
    fn handle(&mut self, bucket: usize, depth: usize) -> Result<PlanHandle> {
        if let Some(h) = self.cache.get(&(bucket, depth)) {
            self.cache_hits += 1;
            return Ok(h.clone());
        }
        self.cache_misses += 1;
        let graph = build_model(&self.config.model, bucket)?;
        let params = self
            .params
            .entry(bucket)
            .or_insert_with(|| random_params(&graph, 0xC0DE + bucket as u64))
            .clone();
        // Depth ladder relative to the model's own baseline (independent
        // of the budget, so the same cache serves any budget): level 0 is
        // dense, level d targets baseline >> d.
        let plans = if depth == 0 {
            Vec::new()
        } else {
            let base = *self
                .baselines
                .entry(bucket)
                .or_insert_with(|| estimate(&graph).peak_bytes);
            autochunk(&graph, (base >> depth).max(1), &self.config.compile).plans
        };
        let tag = format!("{}_native_s{}_d{}", self.config.model, bucket, depth);
        let h = PlanHandle::new(&tag, graph, plans, params);
        let out_shape = h.graph().node(h.graph().outputs[0]).shape.clone();
        self.registry.register(ArtifactMeta {
            tag: tag.clone(),
            hlo_path: String::new(),
            model: self.config.model.clone(),
            mode: if depth == 0 { "native-dense" } else { "native-chunked" }.into(),
            seq: bucket,
            d_model: 0,
            heads: 0,
            layers: 0,
            vocab: 0,
            n_chunks: h.n_chunks_max(),
            num_params: h.graph().params.len(),
            param_names: Vec::new(),
            est_activation_bytes: h.quote().peak_bytes,
            output_shape: out_shape,
        });
        self.cache.insert((bucket, depth), h.clone());
        Ok(h)
    }

    /// Serve an open-loop workload continuously to completion.
    pub fn serve(&mut self, requests: &[Request]) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let width = match self.config.worker_threads {
            0 => pool::num_threads(),
            n => n,
        };
        pool::with_threads(width, || self.serve_inner(requests, Mode::Continuous))
    }

    /// Legacy back-to-back path: one request per wave, in arrival order —
    /// the PR-1 `serve()` semantics on the native backend. Kept as the
    /// determinism baseline and the bench's throughput baseline.
    pub fn serve_serial(
        &mut self,
        requests: &[Request],
    ) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let width = match self.config.worker_threads {
            0 => pool::num_threads(),
            n => n,
        };
        pool::with_threads(width, || self.serve_inner(requests, Mode::Serial))
    }

    /// Admission price of one request under a handle: the memory
    /// planner's exact bound in arena mode (the certified bound for what
    /// the arena executor actually runs — never substituted by the quote,
    /// which can under-model batch-expansion workspace), else the quote.
    /// The quote remains the reported cross-check ceiling: it is almost
    /// always the larger number, and `estimate::planner_gap` surfaces the
    /// difference per plan.
    fn admission_cost(use_arena: bool, h: &PlanHandle) -> usize {
        if use_arena {
            h.memplan().admission_bytes(1)
        } else {
            h.quote().peak_bytes
        }
    }

    fn serve_inner(
        &mut self,
        requests: &[Request],
        mode: Mode,
    ) -> Result<(Vec<EngineResponse>, MetricsReport)> {
        let t0 = Instant::now();
        let mut recorder = Recorder::new();
        let tracker = MemoryTracker::new();
        let (hits0, miss0) = (self.cache_hits, self.cache_misses);
        let mut responses: Vec<EngineResponse> = Vec::with_capacity(requests.len());

        // Arrival-ordered queue (stable by id for equal ticks).
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by_key(|&i| (requests[i].arrival_tick, requests[i].id));
        let mut queue: VecDeque<Pending> =
            order.into_iter().map(|idx| Pending { idx, depth: 0 }).collect();

        let max_batch = match mode {
            Mode::Serial => 1,
            Mode::Continuous => self.config.max_batch.max(1),
        };
        let mut clock: u64 = 0;

        while !queue.is_empty() {
            // Fast-forward the virtual clock to the next arrival.
            let head_arrival = requests[queue[0].idx].arrival_tick;
            if head_arrival > clock {
                clock = head_arrival;
            }

            // ---- admission: pack one wave under the budget
            let mut wave: Vec<(Pending, usize, PlanHandle)> = Vec::new();
            let mut retry: Vec<Pending> = Vec::new();
            let mut remaining = self.config.budget_bytes;
            let mut scan = 0usize;
            while scan < queue.len() && wave.len() < max_batch {
                if requests[queue[scan].idx].arrival_tick > clock {
                    break; // queue is arrival-sorted: nothing further has arrived
                }
                let p = queue[scan];
                let req = &requests[p.idx];
                let Some(bucket) = self.bucket_for(req.seq_len) else {
                    queue.remove(scan);
                    recorder.rejected += 1;
                    responses.push(EngineResponse::rejected(req.id, p.depth));
                    continue;
                };
                let h = self.handle(bucket, p.depth)?;
                let cost = Self::admission_cost(self.config.use_arena, &h);
                if cost > self.config.budget_bytes {
                    // Oversized for the device at this depth.
                    queue.remove(scan);
                    if p.depth < self.config.max_deepen {
                        // Preempt to a deeper-chunked retry, not rejection.
                        recorder.preempted += 1;
                        retry.push(Pending { idx: p.idx, depth: p.depth + 1 });
                    } else {
                        recorder.rejected += 1;
                        responses.push(EngineResponse::rejected(req.id, p.depth));
                    }
                    continue;
                }
                if cost <= remaining {
                    remaining -= cost;
                    queue.remove(scan);
                    wave.push((p, bucket, h));
                    continue;
                }
                // Fits the device but not this wave: leave it and keep
                // scanning for a smaller arrived request (skip-ahead).
                // Head-of-line priority is preserved — the head gets
                // first claim on the full budget every wave — so no
                // request starves.
                scan += 1;
            }
            // Deepened requests retry with head priority next wave.
            for p in retry.into_iter().rev() {
                queue.push_front(p);
            }

            if wave.is_empty() {
                // Only retries/rejections this tick: advance time.
                clock += 1;
                continue;
            }

            // ---- execute the wave: co-resident requests run concurrently
            // on the pool. Leftover headroom (budget − Σ admitted costs)
            // is split evenly across entries and handed to each entry's
            // chunk-concurrency governor: entry i may spend
            // `cost_i + share` bytes, so the wave total stays ≤ budget.
            // In arena mode the governor prices lanes with the planner's
            // exact numbers, so no bound-vs-estimate gap is reserved.
            let per_entry_threads = (pool::num_threads() / wave.len()).max(1);
            let share = remaining / wave.len();
            let use_arena = self.config.use_arena;
            let entries = wave;
            let results: Vec<(u64, Vec<f32>)> = pool::parallel_map(entries.len(), |wi| {
                let (p, _bucket, h) = &entries[wi];
                let req = &requests[p.idx];
                pool::with_threads(per_entry_threads, || {
                    let started = Instant::now();
                    let ins = request_inputs(h.graph(), req, &tracker);
                    let entry_budget = Self::admission_cost(use_arena, h) + share;
                    let opts = ExecOptions {
                        budget_bytes: Some(if use_arena {
                            entry_budget
                        } else {
                            h.quote().governor_budget(entry_budget)
                        }),
                        use_arena,
                    };
                    let (outs, _stats) = h.execute(&ins, &tracker, &opts);
                    let out = outs[0].to_vec_f32();
                    (started.elapsed().as_micros() as u64, out)
                })
            });
            for ((p, bucket, h), (latency_us, output)) in entries.into_iter().zip(results) {
                let req = &requests[p.idx];
                let wait_ticks = clock - req.arrival_tick;
                recorder.record(h.tag(), latency_us, req.seq_len);
                recorder.record_wait(wait_ticks * self.config.tick_us);
                responses.push(EngineResponse {
                    id: req.id,
                    outcome: RequestOutcome::Completed,
                    bucket,
                    depth: p.depth,
                    plan_tag: h.tag().to_string(),
                    wait_ticks,
                    latency_us,
                    output,
                });
            }
            recorder.waves += 1;
            clock += 1;
        }

        recorder.cache_hits = self.cache_hits - hits0;
        recorder.cache_misses = self.cache_misses - miss0;
        recorder.measured_peak_bytes = tracker.peak();
        responses.sort_by_key(|r| r.id);
        let report = recorder.finish(t0.elapsed());
        Ok((responses, report))
    }
}

/// Build a model graph at a bucket's scale (per-model interpretation:
/// tokens, patches, residues, image side).
fn build_model(name: &str, scale: usize) -> Result<Graph> {
    Ok(match name {
        "gpt" => models::gpt(&models::GptConfig { seq: scale, ..Default::default() }),
        "gpt-fused" => models::gpt(&models::GptConfig {
            seq: scale,
            fused_attention: true,
            ..Default::default()
        }),
        "vit" => models::vit(&models::ViTConfig { patches: scale, ..Default::default() }),
        "evoformer" => {
            models::evoformer(&models::EvoformerConfig { seq: scale, ..Default::default() })
        }
        "unet" => models::unet(&models::UNetConfig { image: scale, ..Default::default() }),
        other => crate::bail!("unknown model '{other}' (gpt|gpt-fused|vit|evoformer|unet)"),
    })
}

/// Deterministically materialize a request's graph inputs: token ids feed
/// i32 inputs directly (zero-padded to the bucket); f32 inputs derive a
/// repeatable pattern from the tokens. Allocated on the run's tracker so
/// request inputs count as activation memory, as in production.
fn request_inputs(graph: &Graph, req: &Request, tracker: &MemoryTracker) -> Vec<Tensor> {
    graph
        .inputs
        .iter()
        .map(|&id| {
            let node = graph.node(id);
            let count = numel(&node.shape);
            match node.dtype {
                DType::I32 => {
                    let mut v = vec![0i32; count];
                    let n = req.tokens.len().min(count);
                    v[..n].copy_from_slice(&req.tokens[..n]);
                    Tensor::from_i32(v, &node.shape, Some(tracker.clone()))
                }
                DType::F32 => {
                    let mut v = vec![0f32; count];
                    for (i, slot) in v.iter_mut().enumerate() {
                        let t = if req.tokens.is_empty() {
                            (i % 97) as i32
                        } else {
                            req.tokens[i % req.tokens.len()]
                        };
                        *slot = (t % 512) as f32 / 512.0 - 0.5;
                    }
                    Tensor::from_f32(v, &node.shape, Some(tracker.clone()))
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine(budget: usize) -> ServeEngine {
        ServeEngine::new(EngineConfig {
            model: "gpt".into(),
            budget_bytes: budget,
            max_batch: 4,
            buckets: vec![16, 32],
            worker_threads: 1,
            ..EngineConfig::default()
        })
    }

    #[test]
    fn bucket_routing() {
        let e = tiny_engine(1 << 30);
        assert_eq!(e.bucket_for(10), Some(16));
        assert_eq!(e.bucket_for(16), Some(16));
        assert_eq!(e.bucket_for(17), Some(32));
        assert_eq!(e.bucket_for(33), None);
    }

    #[test]
    fn quote_compiles_once_per_bucket() {
        let mut e = tiny_engine(1 << 30);
        let (b1, q1) = e.quote(10, 0).unwrap().unwrap();
        let (b2, q2) = e.quote(12, 0).unwrap().unwrap();
        assert_eq!(b1, 16);
        assert_eq!(b2, 16);
        assert_eq!(q1.peak_bytes, q2.peak_bytes);
        let (hits, misses) = e.cache_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 1);
        assert!(e.registry().get("gpt_native_s16_d0").is_some());
    }

    #[test]
    fn too_long_request_rejected() {
        let mut e = tiny_engine(1 << 30);
        let reqs = vec![Request::new(0, 64, 1)];
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].outcome, RequestOutcome::Rejected);
        assert_eq!(report.rejected, 1);
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn small_workload_completes() {
        let mut e = tiny_engine(1 << 30);
        let reqs: Vec<Request> =
            (0..3).map(|i| Request::new(i, 8 + i * 4, i as i32).at_tick(0, 500)).collect();
        let (resp, report) = e.serve(&reqs).unwrap();
        assert_eq!(resp.len(), 3);
        assert!(resp.iter().all(|r| r.outcome == RequestOutcome::Completed));
        assert_eq!(report.completed, 3);
        assert!(report.measured_peak_bytes > 0);
        assert!(report.measured_peak_bytes <= 1 << 30);
        // ids come back sorted
        let ids: Vec<usize> = resp.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &resp {
            assert!(!r.output.is_empty());
            assert!(r.output.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn unknown_model_errors() {
        assert!(build_model("nope", 16).is_err());
    }
}
