//! Engine invariant auditor (DESIGN.md §15).
//!
//! The serve engine's correctness rests on conservation laws that hold
//! at every quiescent point (between waves, and at drain):
//!
//! * **block conservation** — `blocks_in_use + free_blocks` equals the
//!   pool's capacity; blocks are never minted or lost, only moved
//!   between the free list and live tables;
//! * **tracker residency** — between waves the only live tracked
//!   allocations are resident KV caches (activations, inputs, and views
//!   are all dropped by wave end), so the run tracker's current bytes
//!   must equal Σ resident KV exactly;
//! * **arena exactness** — the arena executor's outer high-water mark
//!   equals the memory planner's `planned_peak_bytes`, per executed
//!   entry (the PR-3 contract, re-proven live under fault pressure);
//! * **state census** — every request is in exactly one of
//!   {queued, running, responded}; ids are unique within each set, the
//!   sets are pairwise disjoint, and their sizes sum to the workload;
//! * **terminal drain** — when the engine exits, every request holds a
//!   terminal response and every block and tracked byte has returned.
//!
//! The auditor *collects* violations instead of asserting: under chaos
//! injection the engine must degrade gracefully, and a panic inside the
//! checker would itself violate that contract. The chaos soak asserts
//! the collected report is empty.

use std::collections::HashSet;

/// Outcome of an audited serve run: how many quiescent points were
/// checked and every violation found (empty = all invariants held).
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    pub waves_audited: usize,
    pub violations: Vec<String>,
}

/// Between-wave invariant checker for one serve run.
#[derive(Debug, Default)]
pub struct Auditor {
    waves_audited: usize,
    violations: Vec<String>,
}

impl Auditor {
    pub fn new() -> Auditor {
        Auditor::default()
    }

    fn violate(&mut self, msg: String) {
        self.violations.push(msg);
    }

    /// Arena exactness for one executed wave entry: the outer arena's
    /// measured high-water mark must equal the planner's exact peak.
    /// `wave` and `request` anchor the violation to the scheduling moment
    /// it happened at, so a report line (and its trace instant, DESIGN.md
    /// §19) is actionable without replaying the run.
    pub fn check_arena(
        &mut self,
        wave: usize,
        request: usize,
        tag: &str,
        measured: usize,
        planned: usize,
    ) {
        if measured != planned {
            self.violate(format!(
                "wave {wave} req {request}: arena high-water {measured} != planned peak \
                 {planned} for '{tag}'"
            ));
        }
    }

    /// All between-wave invariants. `pool` is paged mode's
    /// `(in_use, free, capacity)` triple (None for contiguous caches);
    /// `queued`/`running`/`done` are request ids per lifecycle state.
    #[allow(clippy::too_many_arguments)]
    pub fn check_wave(
        &mut self,
        wave: usize,
        tracker_current: usize,
        expected_kv: usize,
        pool: Option<(usize, usize, usize)>,
        queued: &[usize],
        running: &[usize],
        done: &[usize],
        total_requests: usize,
    ) {
        self.waves_audited += 1;
        if let Some((in_use, free, capacity)) = pool {
            if in_use + free != capacity {
                self.violate(format!(
                    "wave {wave}: block conservation broken: {in_use} in use + {free} free \
                     != {capacity} pool blocks"
                ));
            }
        }
        if tracker_current != expected_kv {
            self.violate(format!(
                "wave {wave}: tracker holds {tracker_current} bytes but resident KV is \
                 {expected_kv} (non-cache allocation leaked across the wave boundary)"
            ));
        }
        self.check_census(wave, queued, running, done, total_requests);
    }

    fn check_census(
        &mut self,
        wave: usize,
        queued: &[usize],
        running: &[usize],
        done: &[usize],
        total_requests: usize,
    ) {
        let mut seen: HashSet<usize> = HashSet::new();
        for (state, ids) in [("queued", queued), ("running", running), ("responded", done)] {
            let mut local: HashSet<usize> = HashSet::new();
            for &id in ids {
                if !local.insert(id) {
                    self.violate(format!("wave {wave}: request {id} twice in state {state}"));
                }
                if !seen.insert(id) {
                    self.violate(format!(
                        "wave {wave}: request {id} in two lifecycle states (… and {state})"
                    ));
                }
            }
        }
        let counted = queued.len() + running.len() + done.len();
        if counted != total_requests {
            self.violate(format!(
                "wave {wave}: census counts {counted} requests ({} queued, {} running, \
                 {} responded) but the workload has {total_requests}",
                queued.len(),
                running.len(),
                done.len()
            ));
        }
    }

    /// Terminal drain contract: nothing live, nothing leaked, every
    /// request answered.
    #[allow(clippy::too_many_arguments)]
    pub fn check_terminal(
        &mut self,
        tracker_current: usize,
        blocks_in_use: usize,
        live_gens: usize,
        pending_resumes: usize,
        queued: usize,
        responses: usize,
        total_requests: usize,
    ) {
        if tracker_current != 0 {
            self.violate(format!("terminal: tracker still holds {tracker_current} bytes"));
        }
        if blocks_in_use != 0 {
            self.violate(format!("terminal: {blocks_in_use} pool blocks still in use"));
        }
        if live_gens != 0 {
            self.violate(format!("terminal: {live_gens} generations never drained"));
        }
        if pending_resumes != 0 {
            self.violate(format!("terminal: {pending_resumes} resume entries never consumed"));
        }
        if queued != 0 {
            self.violate(format!("terminal: {queued} requests still queued"));
        }
        if responses != total_requests {
            self.violate(format!(
                "terminal: {responses} responses for {total_requests} requests \
                 (a request was silently dropped)"
            ));
        }
    }

    pub fn violations(&self) -> &[String] {
        &self.violations
    }

    pub fn report(&self) -> AuditReport {
        AuditReport {
            waves_audited: self.waves_audited,
            violations: self.violations.clone(),
        }
    }

    pub fn into_report(self) -> AuditReport {
        AuditReport { waves_audited: self.waves_audited, violations: self.violations }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_produces_empty_report() {
        let mut a = Auditor::new();
        a.check_arena(0, 1, "t", 128, 128);
        a.check_wave(0, 1024, 1024, Some((3, 5, 8)), &[1, 2], &[3], &[0], 5);
        a.check_terminal(0, 0, 0, 0, 0, 5, 5);
        let rep = a.into_report();
        assert_eq!(rep.waves_audited, 1);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    }

    #[test]
    fn block_conservation_violation_is_reported() {
        let mut a = Auditor::new();
        a.check_wave(2, 0, 0, Some((3, 4, 8)), &[], &[], &[], 0);
        assert_eq!(a.violations().len(), 1);
        assert!(a.violations()[0].contains("block conservation"), "{}", a.violations()[0]);
    }

    #[test]
    fn tracker_mismatch_is_reported() {
        let mut a = Auditor::new();
        a.check_wave(0, 4096, 2048, None, &[], &[], &[], 0);
        assert_eq!(a.violations().len(), 1);
        assert!(a.violations()[0].contains("resident KV"), "{}", a.violations()[0]);
    }

    #[test]
    fn arena_mismatch_is_reported() {
        let mut a = Auditor::new();
        a.check_arena(3, 7, "gpt_s16", 100, 96);
        assert_eq!(a.violations().len(), 1);
        assert!(a.violations()[0].contains("gpt_s16"));
        assert!(a.violations()[0].contains("wave 3 req 7"), "{}", a.violations()[0]);
    }

    #[test]
    fn census_catches_double_state_and_bad_total() {
        let mut a = Auditor::new();
        // id 7 both queued and running; count mismatch vs total 4
        a.check_wave(1, 0, 0, None, &[7, 8], &[7], &[], 4);
        let v = a.violations();
        assert!(v.iter().any(|m| m.contains("two lifecycle states")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("census counts")), "{v:?}");
    }

    #[test]
    fn census_catches_duplicate_within_state() {
        let mut a = Auditor::new();
        a.check_wave(1, 0, 0, None, &[], &[], &[3, 3], 2);
        assert!(
            a.violations().iter().any(|m| m.contains("twice in state responded")),
            "{:?}",
            a.violations()
        );
    }

    #[test]
    fn terminal_leaks_are_reported() {
        let mut a = Auditor::new();
        a.check_terminal(64, 2, 1, 1, 1, 3, 5);
        assert_eq!(a.violations().len(), 6, "{:?}", a.violations());
    }
}
