//! Paged KV-cache manager: sharing policy over the block pool
//! (DESIGN.md §14).
//!
//! [`crate::tensor::BlockPool`] provides the mechanism (refcounted
//! fixed-size blocks, free-list allocation); this manager owns the
//! *policy* the serve engine runs generations through:
//!
//! * **prefix sharing** — a prompt block is registered under the key
//!   `(bucket, block_index, token_prefix_through_block_end)`; a later
//!   request whose prompt matches the key reuses the block (refcount + 1)
//!   instead of storing a second bitwise-identical copy. Soundness rests
//!   on invariants the repo already pins: causal prefill rows depend only
//!   on their token prefix (padding-invariant), chunk-planned prefill
//!   seeds are bitwise identical to dense ones, and results are width-
//!   and executor-independent — so the shared bytes *are* the bytes the
//!   sharer's own prefill would have produced.
//! * **copy-on-write on divergence** — appending a generated row into a
//!   block held by more than one request first copies the block
//!   ([`BlockPool::copy_block`]) and swaps the private copy into the
//!   appender's table; siblings keep reading the original bit-stably.
//!   Appends into an exclusively-held keyed block write only rows at or
//!   beyond the key's coverage, so the share entry stays valid.
//! * **release** — dropping a table dereferences its blocks; a block
//!   freed by its last reference leaves the share index, so the index
//!   never outlives storage.
//!
//! Lifecycle contract (pinned by `serve_engine.rs` and `kvpage_fuzz.rs`):
//! after every admitted generation has completed or been evicted,
//! `blocks_in_use() == 0` and the run tracker reads zero bytes.

use crate::coordinator::engine::EngineError;
use crate::tensor::{BlockPool, BlockTable, MemoryTracker, SpillStore, Tensor};
use crate::util::fault::{FaultPlan, FaultSite};
use crate::util::trace::{ArgV, TraceScope};
use std::collections::HashMap;
use std::sync::Arc;

/// One spilled KV block: full-block K/V contents per layer (`[h, bt, dh]`
/// row-major), padding rows included so a restore is bitwise exact.
#[derive(Clone, Debug)]
struct SpilledBlock {
    ks: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
}

/// A generation's KV cache parked in the slow tier: block contents by
/// value (no pool storage held). Restoring rebuilds a private block
/// table with bitwise-identical bytes; the restored blocks are exclusive
/// (no prefix-share registration), which is always sound — sharing is an
/// optimization, never a correctness requirement.
#[derive(Clone, Debug, Default)]
pub struct SpilledTable {
    blocks: Vec<SpilledBlock>,
    len: usize,
}

impl SpilledTable {
    /// Cached positions the table held when spilled.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pool blocks a restore will allocate.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }
}

/// Prefix-share key: a block's content is a pure function of the bucket
/// (scale + its parameter set), its index in the table, and the token
/// prefix up to the last position the block holds.
///
/// Storing the full prefix makes a seed O(prompt²) in key bytes; at this
/// repo's bucket scales (≤ a few hundred tokens) that is a few KiB per
/// request and buys an *exactly* sound key with no invalidation
/// machinery. A chained key (parent block id + this block's tokens)
/// would be O(prompt) but needs child-entry invalidation when a parent
/// block id is freed and recycled — deliberately not taken here.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct ShareKey {
    bucket: usize,
    index: usize,
    prefix: Vec<i32>,
}

/// Block-pool owner + prefix-sharing policy for one serve run.
pub struct CacheManager {
    pool: BlockPool,
    /// Prefix index: key → block. Entries are weak — a block freed by its
    /// last table reference is removed (`rev`), so hits always point at
    /// live storage. Keys are `Arc`-shared with `rev` so the prefix
    /// bytes are stored once.
    share: HashMap<Arc<ShareKey>, usize>,
    /// Reverse index for cleanup on free (same `Arc` as the share entry).
    rev: HashMap<usize, Arc<ShareKey>>,
    shared_hits: usize,
    /// Chaos harness (DESIGN.md §15): when installed, block allocations
    /// may be turned into synthetic exhaustion at the `BlockAlloc` site.
    /// Counter-keyed — sound because seed/append only run on the serial
    /// coordinator thread.
    faults: Option<Arc<FaultPlan>>,
    /// Slow-tier byte accounting for spilled KV tables. Deliberately not
    /// the run tracker: fast-tier residency (and the invariant auditor's
    /// `tracker.current == resident_kv` check) must not see parked bytes.
    spill: SpillStore,
    /// KV-lane trace scope (DESIGN.md §19). Sound without locking beyond
    /// the scope's own buffer because every mutating entry point runs on
    /// the serial coordinator thread; `bind_inputs` (the one method the
    /// parallel section calls) is deliberately not instrumented.
    trace: Option<TraceScope>,
}

impl CacheManager {
    pub fn new(
        layers: usize,
        heads: usize,
        block_tokens: usize,
        head_dim: usize,
        pool_blocks: usize,
        tracker: Option<MemoryTracker>,
    ) -> CacheManager {
        CacheManager {
            pool: BlockPool::new(layers, heads, block_tokens, head_dim, pool_blocks, tracker),
            share: HashMap::new(),
            rev: HashMap::new(),
            shared_hits: 0,
            faults: None,
            spill: SpillStore::new(),
            trace: None,
        }
    }

    /// Slow-tier accounting for spilled KV tables (bytes parked, peak,
    /// traffic counters).
    pub fn spill_store(&self) -> &SpillStore {
        &self.spill
    }

    /// Install a fault plan for the `BlockAlloc` injection site.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    /// Install the KV-lane trace scope: block lifecycle events
    /// (`kv.alloc` / `kv.prefix_hit` / `kv.cow` / `kv.free` / `kv.spill` /
    /// `kv.restore` / `kv.discard`) are emitted on it from then on. Block
    /// ids, counts and bytes are pure functions of the serial admission
    /// order, so the event stream is width-independent (DESIGN.md §19).
    pub fn set_trace(&mut self, scope: TraceScope) {
        self.trace = Some(scope);
    }

    /// Pool allocation routed through the chaos harness: an installed
    /// plan may answer with synthetic exhaustion; real exhaustion
    /// surfaces as a typed error either way (never a panic).
    fn alloc_block(&mut self) -> Result<usize, EngineError> {
        if let Some(f) = &self.faults {
            if f.fires_seq(FaultSite::BlockAlloc) {
                return Err(EngineError::Injected { site: FaultSite::BlockAlloc.name() });
            }
        }
        let free = self.pool.free_blocks();
        let id = self.pool.alloc().ok_or(EngineError::PoolExhausted { free })?;
        if let Some(t) = &self.trace {
            t.instant(
                "kv.alloc",
                vec![("block", ArgV::U(id as u64)), ("free", ArgV::U(self.pool.free_blocks() as u64))],
            );
        }
        Ok(id)
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    pub fn block_bytes(&self) -> usize {
        self.pool.block_bytes()
    }

    pub fn layers(&self) -> usize {
        self.pool.layers()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.pool.blocks_in_use()
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    pub fn pool_blocks(&self) -> usize {
        self.pool.pool_blocks()
    }

    /// True residency: blocks in use × block bytes. Shared blocks count
    /// once — this is what the tracker sees and what admission subtracts
    /// from the budget.
    pub fn resident_bytes(&self) -> usize {
        self.pool.resident_bytes()
    }

    /// Prefix-share hits since construction (each hit saved one block).
    pub fn shared_hits(&self) -> usize {
        self.shared_hits
    }

    /// Blocks needed to hold `len` cached positions.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.pool.block_tokens())
    }

    /// Will the next append to `table` consume a fresh block? True at a
    /// block boundary (new tail block) and when the tail block is shared
    /// (copy-on-write takes a block; the original stays with siblings).
    pub fn append_needs_block(&self, table: &BlockTable) -> bool {
        let pos = table.len();
        if pos % self.pool.block_tokens() == 0 {
            return true;
        }
        match table.last_block() {
            Some(last) => self.pool.ref_count(last) > 1,
            None => {
                debug_assert!(false, "non-boundary append on empty table");
                true
            }
        }
    }

    /// Seed a table from prefill outputs (`outs[1 + 2l]`/`outs[2 + 2l]`
    /// are layer `l`'s `[h, bucket, dh]` K/V tensors): prompt blocks are
    /// shared where an identical prefix is already pooled, freshly
    /// written otherwise. `tokens` is the *unpadded* effective prompt
    /// (`len >= plen`); rows `plen..` of `outs` are never stored beyond
    /// the tail block's padding, which no reader observes.
    ///
    /// Admission must have reserved up to `blocks_for(plen)` blocks, so
    /// real pool exhaustion here is a scheduler bug — but it surfaces as
    /// a typed [`EngineError`] (as do injected `BlockAlloc` faults), with
    /// every block the partial table already holds released: a failed
    /// seed leaves the pool exactly as it found it.
    pub fn seed(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        plen: usize,
        outs: &[Tensor],
    ) -> Result<BlockTable, EngineError> {
        assert!(plen >= 1, "seed of empty prompt");
        assert!(tokens.len() >= plen, "prompt shorter than seeded length");
        let bt = self.pool.block_tokens();
        let layers = self.pool.layers();
        assert_eq!(outs.len(), 1 + 2 * layers, "prefill output arity");
        let mut table = BlockTable::new();
        for bi in 0..plen.div_ceil(bt) {
            let r0 = bi * bt;
            let rows = bt.min(plen - r0);
            let key = ShareKey {
                bucket,
                index: bi,
                prefix: tokens[..r0 + rows].to_vec(),
            };
            if let Some(&id) = self.share.get(&key) {
                self.pool.retain(id);
                self.shared_hits += 1;
                if let Some(t) = &self.trace {
                    t.instant(
                        "kv.prefix_hit",
                        vec![
                            ("block", ArgV::U(id as u64)),
                            ("bucket", ArgV::U(bucket as u64)),
                            ("index", ArgV::U(bi as u64)),
                            ("hits", ArgV::U(self.shared_hits as u64)),
                        ],
                    );
                }
                table.push_block(id);
                continue;
            }
            let id = match self.alloc_block() {
                Ok(id) => id,
                Err(e) => {
                    // roll back: the partial table must not leak blocks
                    // (shared refs and freshly written ones alike)
                    self.release_table(table);
                    return Err(e);
                }
            };
            for l in 0..layers {
                let k = outs[1 + 2 * l].slice_axis(1, r0, rows);
                let v = outs[2 + 2 * l].slice_axis(1, r0, rows);
                self.pool.write_rows(id, l, 0, &k, &v);
            }
            let key = Arc::new(key);
            self.share.insert(key.clone(), id);
            self.rev.insert(id, key);
            table.push_block(id);
        }
        table.set_len(plen);
        Ok(table)
    }

    /// Append one decoded position: `outs` is a decode step's output list
    /// (`outs[1 + 2l]`/`outs[2 + 2l]` are layer `l`'s `[h, 1, dh]` new
    /// K/V rows). Allocates a tail block at a boundary, copies-on-write
    /// when the tail block is shared, then writes and advances.
    ///
    /// An allocation failure (real exhaustion or an injected `BlockAlloc`
    /// fault) returns a typed error with `table` unchanged — the caller
    /// can release or retry the generation without partial-append state.
    pub fn append_step(&mut self, table: &mut BlockTable, outs: &[Tensor]) -> Result<(), EngineError> {
        let bt = self.pool.block_tokens();
        let layers = self.pool.layers();
        assert_eq!(outs.len(), 1 + 2 * layers, "decode output arity");
        let pos = table.len();
        let bi = pos / bt;
        if bi == table.blocks().len() {
            let id = self.alloc_block()?;
            table.push_block(id);
        } else {
            assert_eq!(bi + 1, table.blocks().len(), "append not at table tail");
            let cur = table.blocks()[bi];
            if self.pool.ref_count(cur) > 1 {
                // copy-on-write: this generation diverges from siblings
                // still reading the shared prompt block
                let id = self.alloc_block()?;
                self.pool.copy_block(id, cur);
                let old = table.swap_block(bi, id);
                debug_assert_eq!(old, cur);
                if let Some(t) = &self.trace {
                    t.instant(
                        "kv.cow",
                        vec![("from", ArgV::U(cur as u64)), ("to", ArgV::U(id as u64))],
                    );
                }
                // sibling references keep the original (and its share
                // entry) alive; ours moves to the private copy
                self.release_block(cur);
            }
        }
        let id = table.blocks()[bi];
        for l in 0..layers {
            self.pool.write_rows(id, l, pos % bt, &outs[1 + 2 * l], &outs[2 + 2 * l]);
        }
        table.advance();
        Ok(())
    }

    /// Append a chunked-prefill slice's `n` positions: `outs` is a slice
    /// graph's output list (`outs[1 + 2l]`/`outs[2 + 2l]` are layer `l`'s
    /// `[h, n, dh]` new K/V rows), written from `table.len()` on with the
    /// write split at block boundaries. Grows the table
    /// block-by-block (and copies-on-write a shared tail, though chunk-
    /// seeded tables are private by construction — see below).
    ///
    /// An allocation failure (real exhaustion or an injected `BlockAlloc`
    /// fault) releases every block this call pushed and leaves the
    /// logical length unchanged, so a retried slice starts from exactly
    /// the pre-call state; rows already written into a surviving tail
    /// block sit beyond `len` and are unobservable by contract.
    ///
    /// Chunk-grown blocks are deliberately **not** registered for prefix
    /// sharing: share keys cover whole seeded prompts (see
    /// [`CacheManager::seed`]), and a mid-prefill block's content depends
    /// on slice boundaries only through position — sound to share in
    /// principle, left as future work.
    pub fn append_slice(
        &mut self,
        table: &mut BlockTable,
        outs: &[Tensor],
        n: usize,
    ) -> Result<(), EngineError> {
        let bt = self.pool.block_tokens();
        let layers = self.pool.layers();
        assert_eq!(outs.len(), 1 + 2 * layers, "slice output arity");
        assert!(n >= 1, "empty slice append");
        assert_eq!(outs[1].shape()[1], n, "slice row count");
        let pos0 = table.len();
        let blocks0 = table.blocks().len();
        let mut done = 0usize;
        while done < n {
            let pos = pos0 + done;
            let bi = pos / bt;
            let rows = (bt - pos % bt).min(n - done);
            let prep: Result<(), EngineError> = if bi == table.blocks().len() {
                self.alloc_block().map(|id| table.push_block(id))
            } else {
                assert_eq!(bi + 1, table.blocks().len(), "slice append not at table tail");
                let cur = table.blocks()[bi];
                if self.pool.ref_count(cur) > 1 {
                    self.alloc_block().map(|id| {
                        self.pool.copy_block(id, cur);
                        let old = table.swap_block(bi, id);
                        debug_assert_eq!(old, cur);
                        if let Some(t) = &self.trace {
                            t.instant(
                                "kv.cow",
                                vec![("from", ArgV::U(cur as u64)), ("to", ArgV::U(id as u64))],
                            );
                        }
                        self.release_block(cur);
                    })
                } else {
                    Ok(())
                }
            };
            if let Err(e) = prep {
                while table.blocks().len() > blocks0 {
                    let id = table.pop_block().expect("rollback pops pushed blocks");
                    self.release_block(id);
                }
                debug_assert_eq!(table.len(), pos0);
                return Err(e);
            }
            let id = table.blocks()[bi];
            for l in 0..layers {
                let k = outs[1 + 2 * l].slice_axis(1, done, rows);
                let v = outs[2 + 2 * l].slice_axis(1, done, rows);
                self.pool.write_rows(id, l, pos % bt, &k, &v);
            }
            done += rows;
        }
        table.set_len(pos0 + n);
        Ok(())
    }

    /// Bind a decode step's persistent inputs in graph order — per layer,
    /// all K blocks then all V blocks, table order — appending onto `ins`
    /// (which already holds the token).
    pub fn bind_inputs(&self, table: &BlockTable, ins: &mut Vec<Tensor>) {
        for l in 0..self.pool.layers() {
            for &b in table.blocks() {
                ins.push(self.pool.k(b, l));
            }
            for &b in table.blocks() {
                ins.push(self.pool.v(b, l));
            }
        }
    }

    /// Release every block of a finished (or evicted) generation.
    pub fn release_table(&mut self, table: BlockTable) {
        if let Some(t) = &self.trace {
            if !table.blocks().is_empty() {
                t.instant(
                    "kv.free",
                    vec![
                        ("blocks", ArgV::U(table.blocks().len() as u64)),
                        ("len", ArgV::U(table.len() as u64)),
                    ],
                );
            }
        }
        for &id in table.blocks() {
            self.release_block(id);
        }
    }

    /// Park a generation's KV cache in the slow tier: copy every block's
    /// full contents out by value, then release the pool blocks. Unlike
    /// eviction, the cached rows survive — a later [`Self::restore_table`]
    /// rebuilds them bitwise instead of re-running prefill. Shared blocks
    /// are copied too (siblings keep the original); the spilled copy
    /// restores as a private block.
    pub fn spill_table(&mut self, table: BlockTable) -> SpilledTable {
        let layers = self.pool.layers();
        let mut blocks = Vec::with_capacity(table.blocks().len());
        for &id in table.blocks() {
            let mut ks = Vec::with_capacity(layers);
            let mut vs = Vec::with_capacity(layers);
            for l in 0..layers {
                ks.push(self.pool.k(id, l).to_vec_f32());
                vs.push(self.pool.v(id, l).to_vec_f32());
            }
            blocks.push(SpilledBlock { ks, vs });
        }
        let len = table.len();
        let bytes = blocks.len() * self.block_bytes();
        if let Some(t) = &self.trace {
            t.instant(
                "kv.spill",
                vec![
                    ("bytes", ArgV::U(bytes as u64)),
                    ("blocks", ArgV::U(blocks.len() as u64)),
                    ("len", ArgV::U(len as u64)),
                ],
            );
        }
        self.release_table(table);
        self.spill.on_spill(bytes);
        SpilledTable { blocks, len }
    }

    /// Bring a spilled table back into the pool: allocate a private block
    /// per spilled block and write the parked bytes back verbatim. An
    /// allocation failure (exhaustion or an injected `BlockAlloc` fault)
    /// releases every block this call took and leaves the spilled copy
    /// untouched, so the caller can simply retry later. On success the
    /// slow-tier accounting is settled here — the caller just drops the
    /// spent parked copy ([`Self::discard_spilled`] is for tables that
    /// are *never* restored).
    pub fn restore_table(&mut self, spilled: &SpilledTable) -> Result<BlockTable, EngineError> {
        let layers = self.pool.layers();
        let h = self.pool.heads();
        let bt = self.pool.block_tokens();
        let dh = self.pool.head_dim();
        let mut table = BlockTable::new();
        for b in &spilled.blocks {
            let id = match self.alloc_block() {
                Ok(id) => id,
                Err(e) => {
                    self.release_table(table);
                    return Err(e);
                }
            };
            for l in 0..layers {
                let k = Tensor::from_f32(b.ks[l].clone(), &[h, bt, dh], None);
                let v = Tensor::from_f32(b.vs[l].clone(), &[h, bt, dh], None);
                self.pool.write_rows(id, l, 0, &k, &v);
            }
            table.push_block(id);
        }
        table.set_len(spilled.len);
        let bytes = spilled.blocks.len() * self.block_bytes();
        self.spill.on_restore(bytes);
        if let Some(t) = &self.trace {
            t.instant(
                "kv.restore",
                vec![
                    ("bytes", ArgV::U(bytes as u64)),
                    ("blocks", ArgV::U(spilled.blocks.len() as u64)),
                    ("len", ArgV::U(spilled.len as u64)),
                ],
            );
        }
        Ok(table)
    }

    /// Drop a spilled table without restoring it (generation finished,
    /// failed, or was evicted for real) — slow-tier accounting only.
    pub fn discard_spilled(&self, spilled: SpilledTable) {
        let bytes = spilled.blocks.len() * self.block_bytes();
        if let Some(t) = &self.trace {
            t.instant("kv.discard", vec![("bytes", ArgV::U(bytes as u64))]);
        }
        self.spill.on_discard(bytes);
    }

    fn release_block(&mut self, id: usize) {
        if self.pool.release(id) {
            if let Some(key) = self.rev.remove(&id) {
                // defensive: only drop the entry if it still points here
                if self.share.get(&*key) == Some(&id) {
                    self.share.remove(&*key);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_outs(tokens: &[i32], bucket: usize, layers: usize, h: usize, dh: usize) -> Vec<Tensor> {
        // Deterministic stand-in for prefill outputs: row j is a pure
        // function of the token prefix through j — the same dependence
        // structure causal prefill has, so sharing is sound here too.
        let mut outs = vec![Tensor::zeros(&[1, 1], None)];
        for l in 0..layers {
            for which in 0..2 {
                let mut data = vec![0.0f32; h * bucket * dh];
                let mut hash: i64 = 17 + which as i64;
                for j in 0..bucket {
                    if j < tokens.len() {
                        hash = hash.wrapping_mul(31).wrapping_add(tokens[j] as i64 + 1);
                    } else {
                        hash = hash.wrapping_mul(31).wrapping_add(7);
                    }
                    for hi in 0..h {
                        for d in 0..dh {
                            let v = ((hash as f32) * 1e-6).sin()
                                + (l * 100 + hi * 10 + d) as f32 * 1e-3;
                            data[hi * bucket * dh + j * dh + d] = v;
                        }
                    }
                }
                outs.push(Tensor::from_f32(data, &[h, bucket, dh], None));
            }
        }
        outs
    }

    #[test]
    fn seed_shares_identical_prefixes_and_releases_clean() {
        let tr = MemoryTracker::new();
        let (layers, h, bt, dh) = (2usize, 2usize, 4usize, 3usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 16, Some(tr.clone()));
        let tokens: Vec<i32> = (0..10).map(|i| (i * 3 + 1) as i32).collect();
        let outs = synth_outs(&tokens, 16, layers, h, dh);
        let t1 = m.seed(16, &tokens, 10, &outs).unwrap();
        assert_eq!(t1.blocks().len(), 3); // 4+4+2
        assert_eq!(m.blocks_in_use(), 3);
        assert_eq!(m.shared_hits(), 0);

        // identical prompt: all three blocks shared
        let t2 = m.seed(16, &tokens, 10, &outs).unwrap();
        assert_eq!(m.shared_hits(), 3);
        assert_eq!(m.blocks_in_use(), 3, "no new storage for an identical prompt");
        assert_eq!(t1.blocks(), t2.blocks());

        // longer prompt sharing the first two (full) blocks only
        let mut longer = tokens.clone();
        longer.extend([99, 98, 97]);
        let outs_l = synth_outs(&longer, 16, layers, h, dh);
        let t3 = m.seed(16, &longer, 13, &outs_l).unwrap();
        assert_eq!(m.shared_hits(), 5, "two full blocks shared");
        // block 2 is full for t3 but was keyed partial (10 tokens) by t1,
        // so t3 stores blocks 2 and 3 privately
        assert_eq!(m.blocks_in_use(), 5);
        assert_eq!(&t3.blocks()[..2], &t1.blocks()[..2]);

        // divergent prompt shares nothing
        let mut other = tokens.clone();
        other[0] = 42;
        let outs_o = synth_outs(&other, 16, layers, h, dh);
        let t4 = m.seed(16, &other, 10, &outs_o).unwrap();
        assert_eq!(m.shared_hits(), 5);
        assert_eq!(m.blocks_in_use(), 8);

        for t in [t1, t2, t3, t4] {
            m.release_table(t);
        }
        assert_eq!(m.blocks_in_use(), 0);
        assert_eq!(m.free_blocks(), m.pool_blocks());
        assert_eq!(tr.current(), 0, "all block storage returned");
    }

    #[test]
    fn append_cow_keeps_sibling_reads_bitwise_stable() {
        let (layers, h, bt, dh) = (1usize, 2usize, 4usize, 3usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 8, None);
        let tokens: Vec<i32> = vec![5, 6, 7]; // partial block (3 of 4 rows)
        let outs = synth_outs(&tokens, 8, layers, h, dh);
        let mut a = m.seed(8, &tokens, 3, &outs).unwrap();
        let b = m.seed(8, &tokens, 3, &outs).unwrap();
        assert_eq!(m.shared_hits(), 1);
        assert_eq!(m.blocks_in_use(), 1);
        let shared = b.blocks()[0];
        let before: Vec<u32> =
            m.pool().k(shared, 0).to_vec_f32().iter().map(|x| x.to_bits()).collect();

        // appending to `a` diverges: must CoW, sibling bytes untouched
        assert!(m.append_needs_block(&a), "shared tail block forces a CoW block");
        let step = synth_outs(&[9], 1, layers, h, dh); // [h,1,dh] rows
        m.append_step(&mut a, &step).unwrap();
        assert_eq!(a.len(), 4);
        assert_ne!(a.blocks()[0], shared, "CoW must swap in a private copy");
        assert_eq!(m.blocks_in_use(), 2);
        let after: Vec<u32> =
            m.pool().k(shared, 0).to_vec_f32().iter().map(|x| x.to_bits()).collect();
        assert_eq!(before, after, "sibling block bytes changed under CoW");
        // the private copy carries the shared prefix rows bitwise
        let copy = m.pool().k(a.blocks()[0], 0);
        for hi in 0..h {
            for r in 0..3 {
                for d in 0..dh {
                    assert_eq!(
                        copy.at(&[hi, r, d]).to_bits(),
                        m.pool().k(shared, 0).at(&[hi, r, d]).to_bits()
                    );
                }
            }
        }

        m.release_table(a);
        m.release_table(b);
        assert_eq!(m.blocks_in_use(), 0);
    }

    /// Bytes at every valid position of the table, in position order —
    /// written rows only, so block padding never enters a comparison.
    fn table_bits(m: &CacheManager, t: &BlockTable) -> Vec<u32> {
        let bt = m.block_tokens();
        let mut out = Vec::new();
        for pos in 0..t.len() {
            let id = t.blocks()[pos / bt];
            for l in 0..m.layers() {
                for ten in [m.pool().k(id, l), m.pool().v(id, l)] {
                    out.extend(
                        ten.slice_axis(1, pos % bt, 1)
                            .to_vec_f32()
                            .iter()
                            .map(|x| x.to_bits()),
                    );
                }
            }
        }
        out
    }

    #[test]
    fn append_slice_matches_stepwise_appends_bitwise() {
        let (layers, h, bt, dh) = (2usize, 2usize, 4usize, 3usize);
        let tokens = vec![5, 6, 7]; // partial tail block: slice starts mid-block
        let n = 6usize; // crosses one boundary and opens a fresh block
        let slice = synth_outs(&[9, 8, 7, 6, 5, 4], n, layers, h, dh);

        let mut ma = CacheManager::new(layers, h, bt, dh, 8, None);
        let outs = synth_outs(&tokens, 8, layers, h, dh);
        let mut ta = ma.seed(8, &tokens, 3, &outs).unwrap();
        ma.append_slice(&mut ta, &slice, n).unwrap();
        assert_eq!(ta.len(), 9);
        assert_eq!(ta.blocks().len(), 3);

        let mut mb = CacheManager::new(layers, h, bt, dh, 8, None);
        let mut tb = mb.seed(8, &tokens, 3, &outs).unwrap();
        for r in 0..n {
            let mut step = vec![Tensor::zeros(&[1, 1], None)];
            for i in 0..2 * layers {
                step.push(slice[1 + i].slice_axis(1, r, 1).to_contiguous(None));
            }
            mb.append_step(&mut tb, &step).unwrap();
        }
        assert_eq!(tb.len(), 9);
        assert_eq!(table_bits(&ma, &ta), table_bits(&mb, &tb), "slice vs stepwise bytes");

        ma.release_table(ta);
        mb.release_table(tb);
        assert_eq!(ma.blocks_in_use(), 0);
        assert_eq!(mb.blocks_in_use(), 0);
    }

    #[test]
    fn append_slice_copies_shared_tail_before_writing() {
        let (layers, h, bt, dh) = (1usize, 2usize, 4usize, 3usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 8, None);
        let tokens = vec![5, 6, 7]; // partial block, shared by two tables
        let outs = synth_outs(&tokens, 8, layers, h, dh);
        let mut a = m.seed(8, &tokens, 3, &outs).unwrap();
        let b = m.seed(8, &tokens, 3, &outs).unwrap();
        let shared = b.blocks()[0];
        let before: Vec<u32> =
            m.pool().k(shared, 0).to_vec_f32().iter().map(|x| x.to_bits()).collect();
        let slice = synth_outs(&[1, 2], 2, layers, h, dh);
        m.append_slice(&mut a, &slice, 2).unwrap();
        assert_ne!(a.blocks()[0], shared, "shared tail must be copied-on-write");
        let after: Vec<u32> =
            m.pool().k(shared, 0).to_vec_f32().iter().map(|x| x.to_bits()).collect();
        assert_eq!(before, after, "sibling bytes changed under slice CoW");
        m.release_table(a);
        m.release_table(b);
        assert_eq!(m.blocks_in_use(), 0);
    }

    #[test]
    fn failed_slice_append_rolls_back_clean() {
        let (layers, h, bt, dh) = (1usize, 1usize, 2usize, 2usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 2, None); // 2-block pool
        let tokens = vec![1, 2, 3];
        let outs = synth_outs(&tokens, 4, layers, h, dh);
        let mut t = m.seed(4, &tokens, 3, &outs).unwrap(); // both blocks held
        assert_eq!(m.free_blocks(), 0);
        // 3 rows: one fits the tail block, the rest need a third block
        let slice = synth_outs(&[7, 8, 9], 3, layers, h, dh);
        let err = m.append_slice(&mut t, &slice, 3);
        assert!(matches!(err, Err(EngineError::PoolExhausted { .. })), "{err:?}");
        assert_eq!(t.len(), 3, "failed slice must not advance the table");
        assert_eq!(t.blocks().len(), 2, "pushed blocks rolled back");
        assert_eq!(m.blocks_in_use(), 2);
        m.release_table(t);
        assert_eq!(m.blocks_in_use(), 0);
        assert_eq!(m.free_blocks(), m.pool_blocks());
    }

    #[test]
    fn spill_restore_roundtrip_is_bitwise_and_accounted() {
        let tr = MemoryTracker::new();
        let (layers, h, bt, dh) = (2usize, 2usize, 4usize, 3usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 8, Some(tr.clone()));
        let tokens: Vec<i32> = (0..10).map(|i| (i * 5 + 2) as i32).collect();
        let outs = synth_outs(&tokens, 16, layers, h, dh);
        let t = m.seed(16, &tokens, 10, &outs).unwrap();
        let before = table_bits(&m, &t);
        let held = t.blocks().len();
        let block_bytes = m.block_bytes();

        let parked = m.spill_table(t);
        assert_eq!(parked.len(), 10);
        assert_eq!(parked.n_blocks(), held);
        assert_eq!(m.blocks_in_use(), 0, "spill releases pool storage");
        assert_eq!(tr.current(), 0, "fast tier empty while parked");
        assert_eq!(m.spill_store().current(), held * block_bytes);

        let r = m.restore_table(&parked).unwrap();
        drop(parked); // restore already settled the slow-tier accounting
        assert_eq!(r.len(), 10);
        assert_eq!(table_bits(&m, &r), before, "restore must be bitwise exact");
        assert_eq!(m.spill_store().current(), 0);
        assert_eq!(m.spill_store().peak(), held * block_bytes);
        m.release_table(r);
        assert_eq!(m.blocks_in_use(), 0);
        assert_eq!(tr.current(), 0);
    }

    #[test]
    fn failed_restore_rolls_back_and_keeps_spilled_copy() {
        let (layers, h, bt, dh) = (1usize, 1usize, 2usize, 2usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 2, None);
        let tokens = vec![1, 2, 3];
        let outs = synth_outs(&tokens, 4, layers, h, dh);
        let t = m.seed(4, &tokens, 3, &outs).unwrap(); // both blocks
        let parked = m.spill_table(t);
        // refill the pool so the restore cannot get its 2 blocks back
        let hog_outs = synth_outs(&[9], 2, layers, h, dh);
        let hog = m.seed(2, &[9], 1, &hog_outs).unwrap();
        let hog2 = m.seed(2, &[8], 1, &synth_outs(&[8], 2, layers, h, dh)).unwrap();
        assert_eq!(m.free_blocks(), 0);
        let err = m.restore_table(&parked);
        assert!(matches!(err, Err(EngineError::PoolExhausted { .. })), "{err:?}");
        assert_eq!(m.blocks_in_use(), 2, "failed restore must roll back its blocks");
        assert_eq!(m.spill_store().current(), 2 * m.block_bytes(), "copy stays parked");
        m.release_table(hog);
        m.release_table(hog2);
        let r = m.restore_table(&parked).unwrap();
        drop(parked); // restore already settled the slow-tier accounting
        assert_eq!(r.len(), 3);
        m.release_table(r);
        assert_eq!(m.blocks_in_use(), 0);
    }

    #[test]
    fn share_entry_dies_with_its_block() {
        let (layers, h, bt, dh) = (1usize, 1usize, 2usize, 2usize);
        let mut m = CacheManager::new(layers, h, bt, dh, 4, None);
        let tokens = vec![1, 2];
        let outs = synth_outs(&tokens, 4, layers, h, dh);
        let t1 = m.seed(4, &tokens, 2, &outs).unwrap();
        m.release_table(t1);
        assert_eq!(m.blocks_in_use(), 0);
        // a fresh identical prompt must NOT hit the dead entry
        let t2 = m.seed(4, &tokens, 2, &outs).unwrap();
        assert_eq!(m.shared_hits(), 0, "stale share entry served a freed block");
        assert_eq!(m.blocks_in_use(), 1);
        m.release_table(t2);
    }
}
