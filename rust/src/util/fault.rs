//! Deterministic fault injection for chaos testing (DESIGN.md §15).
//!
//! A [`FaultPlan`] is a seeded, thread-safe schedule of injected faults:
//! every decision is a pure function of `(seed, site, key)` through an
//! xorshift* mix — no wall clock, no global RNG — so a chaos failure
//! replays exactly from its printed seed, at any `AUTOCHUNK_THREADS`
//! width. Sites that only ever fire on the serial coordinator thread
//! (block allocation) may instead draw from a per-site injection
//! counter ([`FaultPlan::fires_seq`]); sites reached from pool workers
//! must use keys derived from deterministic engine state
//! ([`FaultScope`]), because worker interleaving would make a shared
//! counter order-dependent.
//!
//! The production configuration is *no plan installed*: every hot-path
//! hook is a single `Option` test on [`crate::plan::ExecOptions`] /
//! `EngineConfig`, and no dice are rolled until a plan exists.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Once};

/// Number of named injection sites.
pub const N_SITES: usize = 5;

/// Where a fault may be injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Activation-tracker allocation failure: the executor unwinds
    /// before allocating anything for the entry.
    TrackerAlloc,
    /// Arena slot-allocation failure: the arena executor unwinds before
    /// the run's arena hands out its first slot.
    ArenaAlloc,
    /// `BlockPool` allocation failure: `CacheManager::seed`/`append_step`
    /// behave as if the pool were exhausted.
    BlockAlloc,
    /// Kernel fault: one `_into` result is poisoned with a NaN.
    Kernel,
    /// Synthetic latency spike: the entry stalls briefly; results are
    /// untouched.
    Latency,
}

impl FaultSite {
    /// Every site, in index order.
    pub const ALL: [FaultSite; N_SITES] = [
        FaultSite::TrackerAlloc,
        FaultSite::ArenaAlloc,
        FaultSite::BlockAlloc,
        FaultSite::Kernel,
        FaultSite::Latency,
    ];

    fn index(self) -> usize {
        match self {
            FaultSite::TrackerAlloc => 0,
            FaultSite::ArenaAlloc => 1,
            FaultSite::BlockAlloc => 2,
            FaultSite::Kernel => 3,
            FaultSite::Latency => 4,
        }
    }

    /// Stable name, used for metrics keys and the auditor report.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::TrackerAlloc => "tracker_alloc",
            FaultSite::ArenaAlloc => "arena_alloc",
            FaultSite::BlockAlloc => "block_alloc",
            FaultSite::Kernel => "kernel",
            FaultSite::Latency => "latency",
        }
    }

    /// Destructive sites corrupt or fail the entry they fire on;
    /// latency spikes only cost time. Only destructive fires mark a
    /// request as fault-touched for the bitwise-parity comparison.
    pub fn destructive(self) -> bool {
        !matches!(self, FaultSite::Latency)
    }
}

/// xorshift64* — the deterministic mixer behind every decision.
fn xorshift_star(mut x: u64) -> u64 {
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

/// A seeded schedule of injected faults. Cheap to share (`Arc` it into
/// the engine config); all state is atomic.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Per-site firing rate in per-mille (0 = never, 1000 = always).
    rates: [u64; N_SITES],
    /// Per-site injection counters for [`fires_seq`](Self::fires_seq).
    seq: [AtomicU64; N_SITES],
    /// Per-site count of faults actually fired (decisions that were
    /// true), for metrics and the "was anything injected" check.
    fired: [AtomicU64; N_SITES],
}

impl FaultPlan {
    /// A plan that never fires; raise sites with [`with_rate`](Self::with_rate).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rates: [0; N_SITES],
            seq: Default::default(),
            fired: Default::default(),
        }
    }

    /// Builder: set one site's firing rate in per-mille (clamped to 1000).
    pub fn with_rate(mut self, site: FaultSite, per_mille: u64) -> FaultPlan {
        self.rates[site.index()] = per_mille.min(1000);
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rate(&self, site: FaultSite) -> u64 {
        self.rates[site.index()]
    }

    /// Pure decision: does `site` fire for `key`? Same (seed, site, key)
    /// always answers the same, from any thread.
    pub fn decide(&self, site: FaultSite, key: u64) -> bool {
        let rate = self.rates[site.index()];
        if rate == 0 {
            return false;
        }
        let salt = (site.index() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let x = xorshift_star(self.seed ^ salt ^ xorshift_star(key.wrapping_add(salt)));
        x % 1000 < rate
    }

    /// [`decide`](Self::decide) plus fired-count bookkeeping.
    pub fn fires_keyed(&self, site: FaultSite, key: u64) -> bool {
        let hit = self.decide(site, key);
        if hit {
            self.fired[site.index()].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Counter-keyed decision for sites that only run on the serial
    /// coordinator thread (block allocation): the n-th call site-wide is
    /// the key, so the schedule replays exactly when the call sequence
    /// does. Do not use from pool workers — their interleaving would
    /// reorder the counter.
    pub fn fires_seq(&self, site: FaultSite) -> bool {
        let n = self.seq[site.index()].fetch_add(1, Ordering::Relaxed);
        self.fires_keyed(site, n)
    }

    /// Faults fired so far at `site`.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.fired[site.index()].load(Ordering::Relaxed)
    }

    /// Faults fired so far across every site.
    pub fn total_fired(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.fired(s)).sum()
    }

    /// One-line per-site summary (`seed=… tracker_alloc=2 … total=9`),
    /// for the chaos soak's replay banner and audit artifact.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut s = format!("seed={}", self.seed);
        for site in FaultSite::ALL {
            let _ = write!(s, " {}={}", site.name(), self.fired(site));
        }
        let _ = write!(s, " total={}", self.total_fired());
        s
    }
}

/// Panic payload for an injected failure. The engine's per-wave
/// `catch_unwind` downcasts this back into a typed `EngineError`;
/// [`silence_injected_panics`] keeps the default panic hook from
/// spamming stderr for it.
#[derive(Clone, Copy, Debug)]
pub struct InjectedFault {
    pub site: FaultSite,
    pub key: u64,
}

/// One entry's view of a [`FaultPlan`]: the plan plus a deterministic
/// key derived from serial engine state (request id, step, retry count),
/// so decisions are identical at every pool width. Cloning shares the
/// touched flag — derive per-call keys with [`with_salt`](Self::with_salt).
#[derive(Clone, Debug)]
pub struct FaultScope {
    plan: Arc<FaultPlan>,
    key: u64,
    /// Set when any destructive site fires under this scope (any salt).
    touched: Arc<AtomicBool>,
}

impl FaultScope {
    pub fn new(plan: Arc<FaultPlan>, key: u64) -> FaultScope {
        FaultScope {
            plan,
            key,
            touched: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Same plan and touched flag, independent decision stream — used to
    /// key the main and LM-head executions of one entry separately.
    pub fn with_salt(&self, salt: u64) -> FaultScope {
        FaultScope {
            plan: self.plan.clone(),
            key: self.key ^ xorshift_star(salt.wrapping_add(0x5DEE_CE66_D)),
            touched: self.touched.clone(),
        }
    }

    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }

    /// Keyed decision for this scope; marks the scope touched when a
    /// destructive site fires.
    pub fn fires(&self, site: FaultSite) -> bool {
        let hit = self.plan.fires_keyed(site, self.key);
        if hit && site.destructive() {
            self.touched.store(true, Ordering::Relaxed);
        }
        hit
    }

    /// Panic with an [`InjectedFault`] payload when `site` fires. Call
    /// *before* the protected resource is acquired so unwinding cannot
    /// leak accounting; the wave-level `catch_unwind` turns the payload
    /// into a typed error.
    pub fn trip(&self, site: FaultSite) {
        if self.fires(site) {
            std::panic::panic_any(InjectedFault { site, key: self.key });
        }
    }

    /// Stall briefly when the latency site fires. Affects wall time
    /// only — decisions and results are untouched.
    pub fn maybe_latency(&self) {
        if self.fires(FaultSite::Latency) {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    /// Did any destructive site fire under this scope (any salt)?
    pub fn touched(&self) -> bool {
        self.touched.load(Ordering::Relaxed)
    }
}

/// Install a process-wide panic hook that swallows [`InjectedFault`]
/// payloads (they are caught and handled at the wave boundary) while
/// delegating every real panic to the previous hook. Idempotent.
pub fn silence_injected_panics() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedFault>().is_none() {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_and_seed_dependent() {
        let a = FaultPlan::new(7).with_rate(FaultSite::Kernel, 500);
        let b = FaultPlan::new(7).with_rate(FaultSite::Kernel, 500);
        let c = FaultPlan::new(8).with_rate(FaultSite::Kernel, 500);
        let sched = |p: &FaultPlan| {
            (0..256).map(|k| p.decide(FaultSite::Kernel, k)).collect::<Vec<_>>()
        };
        assert_eq!(sched(&a), sched(&b), "same seed, same schedule");
        assert_ne!(sched(&a), sched(&c), "different seed, different schedule");
        assert!(sched(&a).iter().any(|&f| f) && sched(&a).iter().any(|&f| !f));
    }

    #[test]
    fn rate_extremes() {
        let never = FaultPlan::new(3);
        let always = FaultPlan::new(3).with_rate(FaultSite::BlockAlloc, 1000);
        for k in 0..64 {
            assert!(!never.decide(FaultSite::BlockAlloc, k));
            assert!(always.decide(FaultSite::BlockAlloc, k));
        }
    }

    #[test]
    fn sites_draw_independent_streams() {
        let p = FaultPlan::new(11)
            .with_rate(FaultSite::TrackerAlloc, 500)
            .with_rate(FaultSite::Kernel, 500);
        let a: Vec<bool> = (0..256).map(|k| p.decide(FaultSite::TrackerAlloc, k)).collect();
        let b: Vec<bool> = (0..256).map(|k| p.decide(FaultSite::Kernel, k)).collect();
        assert_ne!(a, b, "per-site salts must decorrelate the streams");
    }

    #[test]
    fn seq_schedule_replays() {
        let run = || {
            let p = FaultPlan::new(42).with_rate(FaultSite::BlockAlloc, 300);
            (0..128).map(|_| p.fires_seq(FaultSite::BlockAlloc)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fired_counts_and_report() {
        let p = FaultPlan::new(5).with_rate(FaultSite::Kernel, 1000);
        assert!(p.fires_keyed(FaultSite::Kernel, 1));
        assert!(p.fires_keyed(FaultSite::Kernel, 2));
        assert_eq!(p.fired(FaultSite::Kernel), 2);
        assert_eq!(p.total_fired(), 2);
        let r = p.report();
        assert!(r.contains("seed=5") && r.contains("kernel=2"), "{r}");
    }

    #[test]
    fn scope_touched_only_by_destructive_fires() {
        let plan = Arc::new(FaultPlan::new(1).with_rate(FaultSite::Latency, 1000));
        let s = FaultScope::new(plan, 9);
        assert!(s.fires(FaultSite::Latency));
        assert!(!s.touched(), "latency spikes are not destructive");

        let plan = Arc::new(FaultPlan::new(1).with_rate(FaultSite::Kernel, 1000));
        let s = FaultScope::new(plan, 9);
        assert!(!s.touched());
        assert!(s.fires(FaultSite::Kernel));
        assert!(s.touched());
        assert!(s.with_salt(3).touched(), "salted scopes share the flag");
    }

    #[test]
    fn trip_panics_with_typed_payload() {
        silence_injected_panics();
        let plan = Arc::new(FaultPlan::new(2).with_rate(FaultSite::TrackerAlloc, 1000));
        let s = FaultScope::new(plan, 4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.trip(FaultSite::TrackerAlloc)
        }))
        .unwrap_err();
        let f = err.downcast_ref::<InjectedFault>().expect("typed payload");
        assert_eq!(f.site, FaultSite::TrackerAlloc);
        assert_eq!(f.key, 4);
    }
}
