//! Structured tracing and memory-timeline subsystem (DESIGN.md §19).
//!
//! A zero-dependency, thread-safe trace collector: scopes record spans,
//! instant events, and counter samples into per-scope buffers; the trace
//! merges and orders them at export time. Two exports share the same
//! event stream:
//!
//! * [`Trace::chrome_json`] — Chrome trace-event JSON (`ph:"X"/"i"/"C"`,
//!   `tid` = lane, `pid` = engine), loadable in Perfetto or
//!   `chrome://tracing`.
//! * [`Trace::canonical`] — a timestamp-free text rendering used by the
//!   determinism tests: trace *content* (event names, args, per-lane
//!   ordering) is identical at any `AUTOCHUNK_THREADS` width for the
//!   same seed; only timestamps may differ.
//!
//! Determinism contract: every event is attributed to a *lane* (a
//! logical timeline — the serial scheduler loop, the KV manager, one
//! wave entry, one chunk iteration) and carries a sequence number
//! assigned from deterministic scheduling state (`seq_base` from the
//! wave/region ordinal plus a per-scope counter), never from cross-lane
//! arrival order. Sorting by `(lane, seq)` therefore reconstructs the
//! same stream regardless of how the OS interleaved the worker threads.
//! Recorded args must themselves be width-independent (no pool widths,
//! no governed degrees, no latencies — durations live only in the
//! timestamp fields the canonical export strips).
//!
//! Cost contract: tracing is strictly zero-cost when disabled. Every
//! instrumentation site is gated on an `Option` (`ExecOptions.trace`,
//! an engine-held `Option<TraceScope>`): the disabled path is a single
//! `None` branch with no allocation, no locking, and no clock read —
//! pinned by `trace_disabled_is_inert` below and the serve-level
//! bitwise test in `tests/trace.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Fixed lane ids for the engine's serial timelines.
pub const LANE_ENGINE: u64 = 0;
/// KV / cache-manager events (all emitted from the serial coordinator).
pub const LANE_KV: u64 = 1;
/// Plan compile / chunk-search spans.
pub const LANE_COMPILE: u64 = 2;
/// First wave-entry lane; entry `i` of a wave runs on `LANE_WAVE_BASE + i`.
pub const LANE_WAVE_BASE: u64 = 16;

/// Lane for wave entry `i` (the entry's position in the admitted wave,
/// which is deterministic — never the worker-thread index).
pub fn wave_lane(entry: usize) -> u64 {
    LANE_WAVE_BASE + entry as u64
}

/// Sub-lane for chunk iteration `iter` under `parent`. Keyed by the
/// *iteration ordinal* (not the lane slot the governor assigned), so the
/// lane layout is identical whether the chunk loop ran serial or at any
/// concurrency degree.
pub fn chunk_lane(parent: u64, iter: usize) -> u64 {
    (parent + 1) * 8192 + iter as u64
}

/// One recorded argument value.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgV {
    U(u64),
    I(i64),
    F(f64),
    S(String),
}

impl ArgV {
    fn fmt_json(&self, out: &mut String) {
        match self {
            ArgV::U(v) => out.push_str(&v.to_string()),
            ArgV::I(v) => out.push_str(&v.to_string()),
            ArgV::F(v) => {
                // Rust's f64 Display is always a valid JSON number for
                // finite values; NaN/inf degrade to 0 (JSON has neither).
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push('0');
                }
            }
            ArgV::S(s) => json_escape(s, out),
        }
    }

    fn fmt_canon(&self, out: &mut String) {
        match self {
            ArgV::U(v) => out.push_str(&v.to_string()),
            ArgV::I(v) => out.push_str(&v.to_string()),
            ArgV::F(v) => out.push_str(&format!("{v}")),
            ArgV::S(s) => {
                out.push('"');
                out.push_str(s);
                out.push('"');
            }
        }
    }
}

/// Event phase: complete span, instant, or counter sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
    Counter,
}

/// One trace event. `ts_us`/`dur_us` are wall-clock (relative to the
/// trace epoch) and excluded from the determinism contract; everything
/// else must be width-independent.
#[derive(Clone, Debug)]
pub struct Event {
    pub lane: u64,
    pub seq: u64,
    pub ts_us: u64,
    pub dur_us: u64,
    pub kind: EventKind,
    pub name: String,
    pub args: Vec<(&'static str, ArgV)>,
}

impl Event {
    /// Does this event mention request `id` (scalar `req` arg or a
    /// `reqs` CSV list from a batched entry)?
    pub fn mentions_request(&self, id: usize) -> bool {
        for (k, v) in &self.args {
            match (*k, v) {
                ("req", ArgV::U(r)) if *r == id as u64 => return true,
                ("reqs", ArgV::S(s)) => {
                    if s.split(',').any(|p| p.trim().parse::<usize>() == Ok(id)) {
                        return true;
                    }
                }
                _ => {}
            }
        }
        false
    }
}

/// Trace header: replay coordinates recorded alongside the events so a
/// trace composes with the fault-replay workflow. Width-dependent facts
/// (thread count) intentionally live here and *only* here — the header
/// is excluded from the canonical export.
#[derive(Clone, Debug, Default)]
pub struct TraceHeader {
    /// Fault-plan seed, when the run had injection enabled
    /// (`AUTOCHUNK_CHAOS_SEED` replays it).
    pub fault_seed: Option<u64>,
    /// Free-form config pairs (model, budget, arena/batch flags, ...).
    pub config: Vec<(String, String)>,
}

struct Shared {
    t0: Instant,
    header: TraceHeader,
    buffers: Mutex<Vec<Arc<Mutex<Vec<Event>>>>>,
}

/// A trace collector: cheap to clone, shared by every scope it spawns.
#[derive(Clone)]
pub struct Trace {
    shared: Arc<Shared>,
}

impl Trace {
    pub fn new(header: TraceHeader) -> Trace {
        Trace {
            shared: Arc::new(Shared {
                t0: Instant::now(),
                header,
                buffers: Mutex::new(Vec::new()),
            }),
        }
    }

    /// A new scope writing to `lane` with sequence numbers from 0.
    pub fn scope(&self, lane: u64) -> TraceScope {
        self.scope_based(lane, 0)
    }

    /// A new scope writing to `lane` with sequence numbers from
    /// `seq_base` — the caller supplies a deterministic base (e.g.
    /// `wave << 44`) so reused lanes order correctly across epochs.
    pub fn scope_based(&self, lane: u64, seq_base: u64) -> TraceScope {
        let buf = Arc::new(Mutex::new(Vec::new()));
        self.shared.buffers.lock().unwrap().push(buf.clone());
        TraceScope {
            shared: self.shared.clone(),
            buf,
            lane,
            seq_base,
            seq: Arc::new(AtomicU64::new(0)),
            children: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn header(&self) -> &TraceHeader {
        &self.shared.header
    }

    /// Snapshot of all events, ordered by `(lane, seq)` — the
    /// deterministic stream both exports render.
    pub fn events(&self) -> Vec<Event> {
        let buffers = self.shared.buffers.lock().unwrap();
        let mut all: Vec<Event> = Vec::new();
        for b in buffers.iter() {
            all.extend(b.lock().unwrap().iter().cloned());
        }
        all.sort_by(|a, b| (a.lane, a.seq).cmp(&(b.lane, b.seq)));
        all
    }

    /// Chrome trace-event JSON (Perfetto / `chrome://tracing` loadable):
    /// `{"traceEvents":[...],"otherData":{...}}` with `ph:"X"` spans,
    /// `ph:"i"` instants, `ph:"C"` counters, plus `ph:"M"` metadata
    /// naming the process and the known lanes.
    pub fn chrome_json(&self) -> String {
        let events = self.events();
        let mut out = String::with_capacity(4096 + events.len() * 96);
        out.push_str("{\"traceEvents\":[");
        out.push_str(
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
             \"args\":{\"name\":\"autochunk-engine\"}}",
        );
        let mut lanes: Vec<u64> = events.iter().map(|e| e.lane).collect();
        lanes.sort_unstable();
        lanes.dedup();
        for lane in &lanes {
            out.push(',');
            out.push_str(&format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{lane},\
                 \"args\":{{\"name\":",
            ));
            json_escape(&lane_name(*lane), &mut out);
            out.push_str("}}");
        }
        for e in &events {
            out.push(',');
            out.push_str("{\"name\":");
            json_escape(&e.name, &mut out);
            out.push_str(&format!(
                ",\"cat\":\"autochunk\",\"pid\":1,\"tid\":{},\"ts\":{}",
                e.lane, e.ts_us
            ));
            match e.kind {
                EventKind::Span => out.push_str(&format!(",\"ph\":\"X\",\"dur\":{}", e.dur_us)),
                EventKind::Instant => out.push_str(",\"ph\":\"i\",\"s\":\"t\""),
                EventKind::Counter => out.push_str(",\"ph\":\"C\""),
            }
            out.push_str(",\"args\":{");
            for (i, (k, v)) in e.args.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json_escape(k, &mut out);
                out.push(':');
                v.fmt_json(&mut out);
            }
            out.push_str("}}");
        }
        out.push_str("],\"otherData\":{");
        let h = self.header();
        json_escape("fault_seed", &mut out);
        out.push(':');
        match h.fault_seed {
            Some(s) => out.push_str(&s.to_string()),
            None => out.push_str("null"),
        }
        for (k, v) in &h.config {
            out.push(',');
            json_escape(k, &mut out);
            out.push(':');
            json_escape(v, &mut out);
        }
        out.push_str("}}");
        out
    }

    /// Timestamp-free text rendering of the event stream: one line per
    /// event, ordered by `(lane, seq)`, with every recorded arg. Two
    /// same-seed runs at different pool widths must render identically
    /// — this is the artifact the determinism tests compare. The header
    /// (which records width-dependent facts) is deliberately excluded.
    pub fn canonical(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&format!("lane={} seq={} ", e.lane, e.seq));
            out.push_str(match e.kind {
                EventKind::Span => "X ",
                EventKind::Instant => "i ",
                EventKind::Counter => "C ",
            });
            out.push_str(&e.name);
            for (k, v) in &e.args {
                out.push(' ');
                out.push_str(k);
                out.push('=');
                v.fmt_canon(&mut out);
            }
            out.push('\n');
        }
        out
    }
}

fn lane_name(lane: u64) -> String {
    match lane {
        LANE_ENGINE => "scheduler".into(),
        LANE_KV => "kv-cache".into(),
        LANE_COMPILE => "plan-compile".into(),
        // Wave entries are bounded by max_batch (≪ 8192), so everything
        // at or above the first derived band is a chunk sub-lane.
        l if l >= 8192 => {
            let parent = l / 8192 - 1;
            format!("chunk-lane {} of {}", l % 8192, lane_name(parent))
        }
        l if l >= LANE_WAVE_BASE => format!("wave-entry {}", l - LANE_WAVE_BASE),
        l => format!("lane {l}"),
    }
}

fn json_escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// An in-flight span: the sequence number is reserved at `begin` (so
/// per-lane ordering reflects start order), the event is recorded at
/// `end` with the measured duration.
pub struct SpanStart {
    seq: u64,
    at: Instant,
}

/// A handle that records events on one lane. Clones share the buffer
/// and sequence counter; [`TraceScope::child`] opens a fresh buffer on a
/// derived lane (chunk sub-lanes).
#[derive(Clone)]
pub struct TraceScope {
    shared: Arc<Shared>,
    buf: Arc<Mutex<Vec<Event>>>,
    lane: u64,
    seq_base: u64,
    seq: Arc<AtomicU64>,
    children: Arc<AtomicU64>,
}

impl TraceScope {
    pub fn lane(&self) -> u64 {
        self.lane
    }

    fn next_seq(&self) -> u64 {
        self.seq_base + self.seq.fetch_add(1, Ordering::Relaxed)
    }

    fn now_us(&self) -> u64 {
        self.shared.t0.elapsed().as_micros() as u64
    }

    /// Reserve a span's slot in the lane order and start its clock.
    pub fn begin(&self) -> SpanStart {
        SpanStart { seq: self.next_seq(), at: Instant::now() }
    }

    /// Close a span opened with [`TraceScope::begin`].
    pub fn end(&self, start: SpanStart, name: &str, args: Vec<(&'static str, ArgV)>) {
        let dur_us = start.at.elapsed().as_micros() as u64;
        let ts_us = self.now_us().saturating_sub(dur_us);
        self.buf.lock().unwrap().push(Event {
            lane: self.lane,
            seq: start.seq,
            ts_us,
            dur_us,
            kind: EventKind::Span,
            name: name.to_string(),
            args,
        });
    }

    /// Record an instant event.
    pub fn instant(&self, name: &str, args: Vec<(&'static str, ArgV)>) {
        self.buf.lock().unwrap().push(Event {
            lane: self.lane,
            seq: self.next_seq(),
            ts_us: self.now_us(),
            dur_us: 0,
            kind: EventKind::Instant,
            name: name.to_string(),
            args,
        });
    }

    /// Record a counter sample (all args numeric; Perfetto renders each
    /// key as a counter track).
    pub fn counter(&self, name: &str, args: Vec<(&'static str, ArgV)>) {
        self.buf.lock().unwrap().push(Event {
            lane: self.lane,
            seq: self.next_seq(),
            ts_us: self.now_us(),
            dur_us: 0,
            kind: EventKind::Counter,
            name: name.to_string(),
            args,
        });
    }

    /// A scope on a derived lane with its own buffer (chunk sub-lanes).
    /// The child's sequence namespace nests under the parent's
    /// (`parent.seq_base + seq_base`), so a sub-lane reused by a later
    /// epoch of the parent (a new wave reusing an entry lane) never
    /// collides with an earlier epoch's events.
    pub fn child(&self, lane: u64, seq_base: u64) -> TraceScope {
        let buf = Arc::new(Mutex::new(Vec::new()));
        self.shared.buffers.lock().unwrap().push(buf.clone());
        TraceScope {
            shared: self.shared.clone(),
            buf,
            lane,
            seq_base: self.seq_base.wrapping_add(seq_base),
            seq: Arc::new(AtomicU64::new(0)),
            children: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Next derive-block ordinal for this scope: called once per
    /// chunk-region firing (serial within a lane, so deterministic) and
    /// shifted into child `seq_base`s to keep reused sub-lanes ordered.
    pub fn derive_block(&self) -> u64 {
        self.children.fetch_add(1, Ordering::Relaxed)
    }
}

/// `AUTOCHUNK_TRACE=<path>`: when set, the serve engine records a trace
/// and writes the Chrome JSON to `<path>` at the end of each serve call
/// (latched once per process, like the other env toggles).
pub fn trace_path_from_env() -> Option<&'static str> {
    static ENV: std::sync::OnceLock<Option<String>> = std::sync::OnceLock::new();
    ENV.get_or_init(|| std::env::var("AUTOCHUNK_TRACE").ok().filter(|p| !p.is_empty()))
        .as_deref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_sort_by_lane_then_seq() {
        let t = Trace::new(TraceHeader::default());
        let a = t.scope_based(5, 100);
        let b = t.scope(3);
        a.instant("late", vec![]);
        b.instant("early", vec![("k", ArgV::U(1))]);
        b.counter("c", vec![("v", ArgV::I(-2))]);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!((evs[0].lane, evs[0].seq), (3, 0));
        assert_eq!((evs[1].lane, evs[1].seq), (3, 1));
        assert_eq!((evs[2].lane, evs[2].seq), (5, 100));
    }

    #[test]
    fn span_reserves_seq_at_begin() {
        let t = Trace::new(TraceHeader::default());
        let s = t.scope(0);
        let outer = s.begin();
        s.instant("inside", vec![]);
        s.end(outer, "outer", vec![]);
        let evs = t.events();
        assert_eq!(evs[0].name, "outer");
        assert_eq!(evs[0].kind, EventKind::Span);
        assert_eq!(evs[1].name, "inside");
    }

    #[test]
    fn canonical_strips_timestamps() {
        let t = Trace::new(TraceHeader::default());
        let s = t.scope(0);
        let sp = s.begin();
        std::thread::sleep(std::time::Duration::from_millis(1));
        s.end(sp, "work", vec![("n", ArgV::U(7))]);
        let c = t.canonical();
        assert_eq!(c, "lane=0 seq=0 X work n=7\n");
    }

    #[test]
    fn chrome_json_shape() {
        let t = Trace::new(TraceHeader {
            fault_seed: Some(42),
            config: vec![("model".into(), "gpt".into())],
        });
        let s = t.scope(LANE_ENGINE);
        let sp = s.begin();
        s.end(sp, "wave", vec![("wave", ArgV::U(0))]);
        s.instant("admission", vec![("decision", ArgV::S("admit".into()))]);
        s.counter("mem", vec![("live", ArgV::U(1024))]);
        let j = t.chrome_json();
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"ph\":\"i\""));
        assert!(j.contains("\"ph\":\"C\""));
        assert!(j.contains("\"ph\":\"M\""));
        assert!(j.contains("\"fault_seed\":42"));
        assert!(j.contains("\"decision\":\"admit\""));
        assert!(j.ends_with("}}"));
    }

    #[test]
    fn json_escaping() {
        let mut out = String::new();
        json_escape("a\"b\\c\nd", &mut out);
        assert_eq!(out, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn child_lanes_are_collision_free() {
        // chunk lanes of the engine-reserved lanes never collide with
        // the fixed lanes or the wave-entry band
        for parent in [LANE_ENGINE, LANE_KV, LANE_COMPILE, wave_lane(0), wave_lane(500)] {
            for iter in 0..4 {
                let l = chunk_lane(parent, iter);
                assert!(l >= 8192, "chunk lane {l} collides with fixed lanes");
            }
        }
        assert_ne!(chunk_lane(wave_lane(0), 0), chunk_lane(wave_lane(1), 0));
    }

    #[test]
    fn mentions_request_matches_scalar_and_csv() {
        let e = Event {
            lane: 0,
            seq: 0,
            ts_us: 0,
            dur_us: 0,
            kind: EventKind::Instant,
            name: "x".into(),
            args: vec![("req", ArgV::U(3))],
        };
        assert!(e.mentions_request(3));
        assert!(!e.mentions_request(4));
        let b = Event { args: vec![("reqs", ArgV::S("1,2,5".into()))], ..e };
        assert!(b.mentions_request(2));
        assert!(b.mentions_request(5));
        assert!(!b.mentions_request(3));
    }

    #[test]
    fn trace_disabled_is_inert() {
        // The disabled fast path is `Option::None` at every site: no
        // scope exists, so no buffer, lock, or clock is touched. This
        // pin documents the contract the instrumentation sites follow.
        let trace: Option<TraceScope> = None;
        let mut branches = 0;
        if let Some(s) = &trace {
            s.instant("never", vec![]);
            branches += 1;
        }
        assert_eq!(branches, 0);
    }
}
