//! Hand-rolled micro-benchmark harness (criterion replacement).
//!
//! `time_median` runs a closure with warmup and reports the median of N
//! timed iterations — robust to scheduler noise on a busy CI box. The
//! figure benches in `rust/benches/` are plain `harness = false` binaries
//! built on this.

use std::time::{Duration, Instant};

/// Median wall time of `iters` runs after `warmup` runs.
pub fn time_median<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Duration {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed()
        })
        .collect();
    samples.sort_unstable();
    samples[samples.len() / 2]
}

/// Milliseconds as f64 (display helper).
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Bytes → MiB (display helper).
pub fn mib(b: usize) -> f64 {
    b as f64 / (1 << 20) as f64
}

/// A minimal markdown-ish table writer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_is_plausible() {
        let d = time_median(|| std::thread::sleep(Duration::from_millis(2)), 1, 3);
        assert!(d >= Duration::from_millis(1));
        assert!(d < Duration::from_millis(200));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "peak"]);
        t.row(vec!["gpt".into(), "12.5M".into()]);
        t.row(vec!["evoformer".into(), "3.1M".into()]);
        let s = t.render();
        assert!(s.contains("gpt"));
        assert!(s.lines().count() == 4);
    }
}
