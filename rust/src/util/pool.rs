//! Scoped worker pool for data-parallel kernels and chunk loops.
//!
//! No external dependencies (DESIGN.md §2): parallel regions spawn
//! `std::thread::scope` workers, so borrows of inputs/outputs stay plain
//! references and nothing outlives the call. Work is distributed over
//! *disjoint* output slabs — each worker writes its own range and the
//! per-element arithmetic is untouched — so results are bitwise identical
//! to the serial path at every width.
//!
//! Width selection, in precedence order:
//! 1. [`with_threads`] — a per-thread override, used by the serving
//!    coordinator to size each worker and by benches/tests to compare
//!    widths within one process;
//! 2. the `AUTOCHUNK_THREADS` environment variable (`1` = exact legacy
//!    single-threaded behaviour);
//! 3. `std::thread::available_parallelism()`.
//!
//! Inside a pool worker the effective width is pinned to 1: when the
//! chunked executor runs chunk iterations in parallel, the kernels inside
//! each iteration run serially instead of oversubscribing the machine.

use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    static OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Below this much per-element work a parallel region runs inline.
/// Workers are spawned per region (no persistent pool), which costs on
/// the order of ~100µs of spawn/join; 256K element-ops is comfortably
/// past break-even for the cheapest (copy/add-class) kernels while still
/// letting every model-sized op parallelize.
const MIN_PAR_WORK: usize = 256 * 1024;

fn env_threads() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        match std::env::var("AUTOCHUNK_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(n) if n >= 1 => n,
            _ => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(16),
        }
    })
}

/// Effective worker count for parallel regions entered on this thread.
pub fn num_threads() -> usize {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(env_threads)
}

/// Run `f` with the pool width forced to `n` on the current thread
/// (restored afterwards, panic-safe).
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let _restore = Restore(OVERRIDE.with(|o| o.replace(Some(n.max(1)))));
    f()
}

/// Pin nested parallel regions on this (worker) thread to width 1.
fn serialize_nested() {
    OVERRIDE.with(|o| o.set(Some(1)));
}

/// Round-robin `jobs` over up to [`num_threads`] scoped workers.
fn run_jobs<J: Send>(jobs: Vec<J>, run: impl Fn(J) + Sync) {
    let threads = num_threads().min(jobs.len());
    if threads <= 1 {
        for j in jobs {
            run(j);
        }
        return;
    }
    let mut groups: Vec<Vec<J>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, j) in jobs.into_iter().enumerate() {
        groups[i % threads].push(j);
    }
    let run = &run;
    std::thread::scope(|s| {
        for group in groups {
            s.spawn(move || {
                serialize_nested();
                for j in group {
                    run(j);
                }
            });
        }
    });
}

/// Evaluate `f(0..tasks)` on the pool, returning results in task order.
/// Results are identical to the serial evaluation (tasks are independent);
/// only wall time changes with the width.
pub fn parallel_map<T: Send>(tasks: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let threads = num_threads().min(tasks);
    if threads <= 1 {
        return (0..tasks).map(f).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<T>> = (0..tasks).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            handles.push(s.spawn(move || {
                serialize_nested();
                let mut got = Vec::new();
                let mut i = t;
                while i < tasks {
                    got.push((i, f(i)));
                    i += threads;
                }
                got
            }));
        }
        for h in handles {
            for (i, v) in h.join().expect("pool worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots.into_iter().map(|v| v.expect("task not run")).collect()
}

/// Split `out` into consecutive slabs of the given lengths and run
/// `f(slab_index, slab)` for each, in parallel when `work` (an estimate of
/// total element-ops) justifies it. `lens` must sum to `out.len()`.
pub fn par_slabs(
    out: &mut [f32],
    lens: &[usize],
    work: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(lens.iter().sum::<usize>(), out.len(), "slab lengths");
    let mut slabs: Vec<(usize, &mut [f32])> = Vec::with_capacity(lens.len());
    let mut rest = out;
    for (i, &len) in lens.iter().enumerate() {
        let (slab, tail) = std::mem::take(&mut rest).split_at_mut(len);
        slabs.push((i, slab));
        rest = tail;
    }
    let _ = rest;
    if num_threads() <= 1 || work < MIN_PAR_WORK || slabs.len() <= 1 {
        for (i, slab) in slabs {
            f(i, slab);
        }
        return;
    }
    run_jobs(slabs, |(i, slab)| f(i, slab));
}

/// Split `rows` rows of `row_len` elements into contiguous near-equal
/// blocks (one per worker) and run `f(row_start, row_end, block)` on each.
/// The serial path is a single `f(0, rows, out)` call — kernels keep one
/// code path for both.
pub fn par_rows(
    out: &mut [f32],
    rows: usize,
    row_len: usize,
    work: usize,
    f: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(rows * row_len, out.len(), "row geometry");
    let threads = num_threads();
    if threads <= 1 || work < MIN_PAR_WORK || rows <= 1 {
        f(0, rows, out);
        return;
    }
    let blocks = threads.min(rows);
    let per = rows.div_ceil(blocks);
    let mut slabs: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(blocks);
    let mut rest = out;
    let mut r0 = 0usize;
    while r0 < rows {
        let take = per.min(rows - r0);
        let (slab, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
        slabs.push((r0, r0 + take, slab));
        rest = tail;
        r0 += take;
    }
    let _ = rest;
    run_jobs(slabs, |(a, b, slab)| f(a, b, slab));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_threads_overrides_and_restores() {
        let outer = num_threads();
        with_threads(3, || {
            assert_eq!(num_threads(), 3);
            with_threads(1, || assert_eq!(num_threads(), 1));
            assert_eq!(num_threads(), 3);
        });
        assert_eq!(num_threads(), outer);
    }

    #[test]
    fn parallel_map_preserves_order() {
        for width in [1usize, 2, 5] {
            let v = with_threads(width, || parallel_map(23, |i| i * i));
            assert_eq!(v, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn workers_run_nested_regions_serially() {
        let widths = with_threads(4, || parallel_map(8, |_| num_threads()));
        assert!(widths.iter().all(|&w| w == 1), "{widths:?}");
    }

    #[test]
    fn par_rows_fills_every_row_once() {
        let rows = 37;
        let row_len = 5;
        for width in [1usize, 4] {
            let mut out = vec![0.0f32; rows * row_len];
            with_threads(width, || {
                // large fake work so the parallel path is exercised
                par_rows(&mut out, rows, row_len, usize::MAX, |r0, r1, slab| {
                    for (j, v) in slab.iter_mut().enumerate() {
                        let r = r0 + j / row_len;
                        assert!(r < r1);
                        *v += r as f32;
                    }
                });
            });
            let want: Vec<f32> = (0..rows)
                .flat_map(|r| vec![r as f32; row_len])
                .collect();
            assert_eq!(out, want, "width {width}");
        }
    }

    #[test]
    fn par_slabs_uneven_lengths() {
        let lens = [3usize, 0, 7, 1, 5];
        let total: usize = lens.iter().sum();
        for width in [1usize, 3] {
            let mut out = vec![-1.0f32; total];
            with_threads(width, || {
                par_slabs(&mut out, &lens, usize::MAX, |i, slab| {
                    assert_eq!(slab.len(), lens[i]);
                    for v in slab.iter_mut() {
                        *v = i as f32;
                    }
                });
            });
            let mut want = Vec::new();
            for (i, &l) in lens.iter().enumerate() {
                want.extend(vec![i as f32; l]);
            }
            assert_eq!(out, want);
        }
    }

    #[test]
    fn small_work_stays_inline() {
        // below MIN_PAR_WORK the region must not spawn: observable via
        // num_threads() staying at the caller's width inside `f` (workers
        // would see 1 from another thread's serialize_nested).
        with_threads(4, || {
            let mut out = vec![0.0f32; 8];
            par_rows(&mut out, 8, 1, 8, |_, _, _| {
                assert_eq!(num_threads(), 4, "inline path expected");
            });
        });
    }
}
