//! Small utilities shared across layers: a micro-benchmark timer
//! (criterion is not in the offline dependency set — see DESIGN.md), the
//! internal error/context plumbing, the deterministic fault-injection
//! harness, and the scoped worker pool behind all kernel- and
//! chunk-level parallelism.

pub mod bench;
pub mod error;
pub mod fault;
pub mod pool;
pub mod trace;
