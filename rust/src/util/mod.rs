//! Small utilities: a micro-benchmark timer (criterion is not in the
//! vendored dependency set — see DESIGN.md) and formatting helpers shared
//! by the benches.

pub mod bench;
