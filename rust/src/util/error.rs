//! Minimal error/context plumbing (anyhow replacement).
//!
//! The crate builds fully offline (see DESIGN.md §2): instead of depending
//! on `anyhow`, this module provides the tiny subset the codebase uses —
//! a string-carrying [`Error`], the [`anyhow!`]/[`bail!`] macros, and a
//! [`Context`] extension trait for `Result`/`Option`. Context wraps
//! outside-in, so `{e}` prints `outer: inner` like anyhow's `{e:#}`.

use std::fmt;

/// A boxed-free, message-carrying error. Converts from any `std::error`
/// type via the blanket [`From`] impl, so `?` works on io/parse errors.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prefix the message with a context layer.
    pub fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which keeps
// this blanket conversion coherent (no overlap with `From<T> for T`).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias. The second parameter defaults like anyhow's,
/// so both `Result<T>` and `collect::<Result<Vec<_>, ParseIntError>>()`
/// spellings work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result`/`Option`.
pub trait Context<T> {
    /// Wrap an error (or `None`) with a fixed context message.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] from a format string (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &str) -> Result<usize> {
        let n: usize = v.parse().context("parsing count")?;
        if n == 0 {
            bail!("count must be positive, got {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("parsing count:"), "{e}");
    }

    #[test]
    fn bail_and_anyhow_macros() {
        let e = parse("0").unwrap_err();
        assert_eq!(e.to_string(), "count must be positive, got 0");
        let direct = anyhow!("code {}", 42);
        assert_eq!(direct.to_string(), "code 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let w: Option<u8> = Some(3);
        assert_eq!(w.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn context_layers_compose() {
        let base: Result<()> = Err(Error::msg("inner"));
        let e = base
            .context("mid")
            .with_context(|| format!("outer {}", 1))
            .unwrap_err();
        assert_eq!(e.to_string(), "outer 1: mid: inner");
    }
}
