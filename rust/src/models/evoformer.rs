//! Evoformer pair stack (AlphaFold 2, simplified per DESIGN.md §10).
//!
//! Keeps the memory-dominant structure the paper evaluates: triangle
//! multiplication (einsum `ikc,jkc→ijc`) and triangle (per-row) attention
//! with `O(s³)` score tensors, plus the pair transition FFN. The MSA stack
//! and IPA head are unrelated scaffolding for activation-memory purposes
//! and are omitted.

use crate::ir::{Graph, GraphBuilder, NodeId};
use crate::tensor::ops::UnaryOp;
use crate::tensor::reduce::ReduceOp;

/// Evoformer configuration.
#[derive(Clone, Debug)]
pub struct EvoformerConfig {
    /// Number of residues (pair representation is `[seq, seq, c]`).
    pub seq: usize,
    /// Pair channel dimension.
    pub c: usize,
    /// Attention heads in triangle attention.
    pub heads: usize,
    pub blocks: usize,
    pub transition_mult: usize,
}

impl Default for EvoformerConfig {
    fn default() -> Self {
        EvoformerConfig {
            seq: 64,
            c: 32,
            heads: 4,
            blocks: 2,
            transition_mult: 4,
        }
    }
}

/// LayerNorm over the channel (last) axis of `[s, s, c]`.
fn pair_norm(b: &mut GraphBuilder, x: NodeId, c: usize, name: &str) -> NodeId {
    let g = b.param(&format!("{name}.g"), &[c]);
    let beta = b.param(&format!("{name}.b"), &[c]);
    b.layer_norm(x, g, beta, 1e-5)
}

/// Linear on the channel axis: `[s, s, c] @ [c, co] + [co]`.
fn pair_linear(b: &mut GraphBuilder, x: NodeId, ci: usize, co: usize, name: &str) -> NodeId {
    let w = b.param(&format!("{name}.w"), &[ci, co]);
    let bias = b.param(&format!("{name}.b"), &[co]);
    b.linear(x, w, bias)
}

/// Triangle multiplication (outgoing): `out[i,j] = Σₖ left[i,k] ⊙ right[j,k]`.
fn triangle_multiply(
    b: &mut GraphBuilder,
    pair: NodeId,
    s: usize,
    c: usize,
    name: &str,
) -> NodeId {
    let xn = pair_norm(b, pair, c, &format!("{name}.ln"));
    let left = pair_linear(b, xn, c, c, &format!("{name}.left"));
    let lg = pair_linear(b, xn, c, c, &format!("{name}.left_gate"));
    let lgs = b.unary(UnaryOp::Sigmoid, lg);
    let left = b.mul(left, lgs);
    let right = pair_linear(b, xn, c, c, &format!("{name}.right"));
    let rg = pair_linear(b, xn, c, c, &format!("{name}.right_gate"));
    let rgs = b.unary(UnaryOp::Sigmoid, rg);
    let right = b.mul(right, rgs);

    // einsum ikc,jkc->ijc via channel-batched matmul
    let lt = b.transpose(left, &[2, 0, 1]); // [c, i, k]
    let rt = b.transpose(right, &[2, 1, 0]); // [c, k, j]
    let prod = b.matmul(lt, rt); // [c, i, j]
    let prod = b.transpose(prod, &[1, 2, 0]); // [i, j, c]

    let pn = pair_norm(b, prod, c, &format!("{name}.ln_out"));
    let out = pair_linear(b, pn, c, c, &format!("{name}.out"));
    let og = pair_linear(b, xn, c, c, &format!("{name}.out_gate"));
    let ogs = b.unary(UnaryOp::Sigmoid, og);
    let gated = b.mul(out, ogs);
    let _ = s;
    b.add(gated, pair)
}

/// Triangle attention (starting node): per-row attention over columns.
/// Scores are `[s, h, s, s]` — the O(s³) hotspot.
fn triangle_attention(
    b: &mut GraphBuilder,
    pair: NodeId,
    s: usize,
    c: usize,
    h: usize,
    name: &str,
) -> NodeId {
    let dh = c / h;
    let scale = 1.0 / (dh as f32).sqrt();
    let xn = pair_norm(b, pair, c, &format!("{name}.ln"));
    let q = pair_linear(b, xn, c, c, &format!("{name}.q"));
    let k = pair_linear(b, xn, c, c, &format!("{name}.k"));
    let v = pair_linear(b, xn, c, c, &format!("{name}.v"));

    // [s, s, c] -> [s, h, s, dh] (row-batched heads)
    let qh = b.reshape(q, &[s, s, h, dh]);
    let qh = b.transpose(qh, &[0, 2, 1, 3]);
    let kh = b.reshape(k, &[s, s, h, dh]);
    let kh = b.transpose(kh, &[0, 2, 3, 1]); // [s, h, dh, s]
    let vh = b.reshape(v, &[s, s, h, dh]);
    let vh = b.transpose(vh, &[0, 2, 1, 3]);

    let scores = b.matmul(qh, kh); // [s, h, s, s]
    let scaled = b.binary_scalar(crate::tensor::ops::BinaryOp::Mul, scores, scale);
    let probs = b.softmax(scaled, 3);
    let ctx = b.matmul(probs, vh); // [s, h, s, dh]
    let ctx = b.transpose(ctx, &[0, 2, 1, 3]); // [s, s, h, dh]
    let ctx = b.reshape(ctx, &[s, s, c]);

    let out = pair_linear(b, ctx, c, c, &format!("{name}.out"));
    let g = pair_linear(b, xn, c, c, &format!("{name}.gate"));
    let gs = b.unary(UnaryOp::Sigmoid, g);
    let gated = b.mul(out, gs);
    b.add(gated, pair)
}

/// Pair transition: channelwise FFN with expansion.
fn pair_transition(
    b: &mut GraphBuilder,
    pair: NodeId,
    c: usize,
    mult: usize,
    name: &str,
) -> NodeId {
    let xn = pair_norm(b, pair, c, &format!("{name}.ln"));
    let h = pair_linear(b, xn, c, mult * c, &format!("{name}.w1"));
    let a = b.unary(UnaryOp::Relu, h);
    let out = pair_linear(b, a, mult * c, c, &format!("{name}.w2"));
    b.add(out, pair)
}

/// Build the Evoformer pair-stack graph: pair `[s,s,c]` → pair `[s,s,c]`
/// plus a scalar distogram-ish summary head.
pub fn evoformer(cfg: &EvoformerConfig) -> Graph {
    let (s, c) = (cfg.seq, cfg.c);
    assert_eq!(c % cfg.heads, 0);
    let mut b = GraphBuilder::new("evoformer");
    let pair_in = b.input("pair", &[s, s, c]);
    let mut pair = pair_in;
    for bi in 0..cfg.blocks {
        pair = triangle_multiply(&mut b, pair, s, c, &format!("b{bi}.tri_mul"));
        pair = triangle_attention(&mut b, pair, s, c, cfg.heads, &format!("b{bi}.tri_attn"));
        pair = pair_transition(&mut b, pair, c, cfg.transition_mult, &format!("b{bi}.transition"));
    }
    let gf = b.param("lnf.g", &[c]);
    let bf = b.param("lnf.b", &[c]);
    let out = b.layer_norm(pair, gf, bf, 1e-5);
    // distogram-style per-pair logit summary
    let w = b.param("dist.w", &[c, 1]);
    let bias = b.param("dist.b", &[1]);
    let logits = b.linear(out, w, bias); // [s, s, 1]
    let pooled = b.reduce(ReduceOp::Mean, logits, 2, false); // [s, s]
    b.finish(vec![out, pooled])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute, random_inputs, random_params};
    use crate::passes::estimate::estimate;
    use crate::tensor::MemoryTracker;

    #[test]
    fn builds_and_validates() {
        let g = evoformer(&EvoformerConfig { seq: 24, ..Default::default() });
        assert!(g.validate().is_ok());
        assert_eq!(g.node(g.outputs[0]).shape, vec![24, 24, 32]);
        assert_eq!(g.node(g.outputs[1]).shape, vec![24, 24]);
    }

    #[test]
    fn triangle_attention_dominates_memory() {
        let cfg = EvoformerConfig { seq: 48, ..Default::default() };
        let g = evoformer(&cfg);
        let p = estimate(&g);
        let peak = g.node(p.peak_node);
        // O(s³) tensors: [s, h, s, s]
        assert_eq!(
            peak.shape,
            vec![cfg.seq, cfg.heads, cfg.seq, cfg.seq],
            "peak at {:?} {:?}",
            peak.op,
            peak.shape
        );
    }

    #[test]
    fn executes_finite() {
        let g = evoformer(&EvoformerConfig { seq: 16, blocks: 1, ..Default::default() });
        let tracker = MemoryTracker::new();
        let ins = random_inputs(&g, 11, Some(tracker.clone()));
        let ps = random_params(&g, 12);
        let (outs, _) = execute(&g, &ins, &ps, &tracker);
        assert!(outs[0].to_vec_f32().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cubic_memory_growth() {
        let a = estimate(&evoformer(&EvoformerConfig { seq: 48, ..Default::default() })).peak_bytes;
        let b = estimate(&evoformer(&EvoformerConfig { seq: 96, ..Default::default() })).peak_bytes;
        let growth = b as f64 / a as f64;
        assert!(growth > 5.5, "2x seq gave only {growth:.1}x (expect ~8x)");
    }
}
