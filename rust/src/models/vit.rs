//! ViT: vision transformer encoder over patch embeddings.
//!
//! Input is pre-patchified (`[patches, patch_dim]`, i.e. 16×16×3 = 768
//! values per patch); the encoder reuses the GPT transformer block (no
//! causal structure matters for memory).

use super::gpt::transformer_block;
use crate::ir::{Graph, GraphBuilder};

/// ViT configuration.
#[derive(Clone, Debug)]
pub struct ViTConfig {
    /// Number of patches (sequence length of the encoder).
    pub patches: usize,
    /// Flattened patch dimension (16×16 RGB = 768).
    pub patch_dim: usize,
    pub d_model: usize,
    pub heads: usize,
    pub layers: usize,
    pub ff_mult: usize,
    pub classes: usize,
    /// Figure-6 variant: fused memory-efficient attention.
    pub fused_attention: bool,
}

impl Default for ViTConfig {
    fn default() -> Self {
        ViTConfig {
            patches: 1024,
            patch_dim: 768,
            d_model: 192,
            heads: 6,
            layers: 4,
            ff_mult: 4,
            classes: 100,
            fused_attention: false,
        }
    }
}

/// Build the ViT graph: patches → class logits.
pub fn vit(cfg: &ViTConfig) -> Graph {
    let (p, d) = (cfg.patches, cfg.d_model);
    let mut b = GraphBuilder::new(if cfg.fused_attention { "vit_fused" } else { "vit" });

    let patches = b.input("patches", &[p, cfg.patch_dim]);
    let wemb = b.param("patch_proj.w", &[cfg.patch_dim, d]);
    let bemb = b.param("patch_proj.b", &[d]);
    let pos = b.param("pos_emb", &[p, d]);
    let emb = b.linear(patches, wemb, bemb);
    let mut x = b.add(emb, pos);

    for li in 0..cfg.layers {
        let (out, _, _) = transformer_block(
            &mut b,
            x,
            li,
            p,
            d,
            cfg.heads,
            cfg.ff_mult,
            cfg.fused_attention,
            None,
        );
        x = out;
    }

    // mean-pool + classification head
    let gf = b.param("lnf.g", &[d]);
    let bf = b.param("lnf.b", &[d]);
    let xn = b.layer_norm(x, gf, bf, 1e-5);
    let pooled = b.reduce(crate::tensor::reduce::ReduceOp::Mean, xn, 0, false); // [d]
    let pooled2 = b.reshape(pooled, &[1, d]);
    let wh = b.param("head.w", &[d, cfg.classes]);
    let bh = b.param("head.b", &[cfg.classes]);
    let logits = b.linear(pooled2, wh, bh);
    b.finish(vec![logits])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::estimate::estimate;
    use crate::passes::{autochunk, AutoChunkConfig};

    #[test]
    fn builds_and_classifies() {
        let g = vit(&ViTConfig { patches: 64, ..Default::default() });
        assert_eq!(g.node(g.outputs[0]).shape, vec![1, 100]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn autochunk_halves_vit_memory() {
        let g = vit(&ViTConfig { patches: 256, layers: 2, ..Default::default() });
        let base = estimate(&g).peak_bytes;
        let r = autochunk(&g, base / 2, &AutoChunkConfig::default());
        assert!(r.chunked_peak <= base / 2, "{} > {}", r.chunked_peak, base / 2);
    }
}
